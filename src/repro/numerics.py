"""Shared numeric constants and mask→logit-bias helpers.

Single source of truth for the logit-space masking convention used by BOTH
the pure-jnp reference paths (``repro.core``) and the Pallas kernels
(``repro.kernels``): a masked key contributes an additive fp32 bias of
``NEG_INF`` (−1e30) to its logits, softmax statistics guard at
``NEG_INF / 2``, and rows whose keys are all masked produce exact zeros.
Keeping one definition guarantees the two execution paths agree bit-for-bit
on what "masked" means — a drifted constant here shows up as gradient-parity
failures, not crashes.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["NEG_INF", "mask_to_bias", "key_padding_bias",
           "segment_ids_from_offsets"]

NEG_INF = -1e30


def mask_to_bias(valid: jnp.ndarray) -> jnp.ndarray:
    """bool (… L) -> additive fp32 bias 0 / NEG_INF."""
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def key_padding_bias(mask: jnp.ndarray | None, batch: int, length: int) -> jnp.ndarray:
    """(B, L) bool key-validity (or None = all valid) -> (B, L) fp32 bias.

    The dense form every kernel entry point consumes: None materialises as
    zeros so kernel signatures stay mask-free (one code path, no tracing
    forks on mask presence).
    """
    if mask is None:
        return jnp.zeros((batch, length), jnp.float32)
    return mask_to_bias(mask)


def segment_ids_from_offsets(offsets: jnp.ndarray, length: int) -> jnp.ndarray:
    """Packed-varlen offsets ``(S+1,)`` → per-position segment id ``(length,)``.

    Positions in ``[offsets[i], offsets[i+1])`` get id ``i``.  Positions at or
    beyond ``offsets[-1]`` (capacity padding) get id ``S`` — STRICTLY greater
    than every real segment, so an equality test against key segment ids makes
    capacity-tail rows attend nothing real and vice versa.  Trailing repeated
    offsets (empty segments, used to keep the offsets shape static under jit)
    own no positions and therefore never match anything.
    """
    pos = jnp.arange(length, dtype=jnp.int32)
    bounds = jnp.asarray(offsets, jnp.int32)[1:]
    return jnp.searchsorted(bounds, pos, side="right").astype(jnp.int32)
