from repro.models.transformer import (  # noqa: F401
    layer_spec,
    lm_apply,
    lm_cache_init,
    lm_decode_step,
    lm_init,
    lm_loss,
    n_periods,
)
from repro.models.pointcloud import pc_apply, pc_init, pc_loss  # noqa: F401
from repro.models.encdec import (  # noqa: F401
    encdec_cache_init,
    encdec_decode_step,
    encdec_init,
    encdec_loss,
    encode,
)
from repro.models.vlm import vlm_apply, vlm_init, vlm_loss  # noqa: F401
from repro.models.moe import moe_apply, moe_init  # noqa: F401
from repro.models.mamba2 import (  # noqa: F401
    mamba2_apply,
    mamba2_cache_init,
    mamba2_decode,
    mamba2_init,
)
