"""Family-dispatch API: one uniform interface over all model families.

Used by the trainer, the serving engine, the dry-run and the smoke tests:

    api = model_api(mcfg)
    params = api.init(key)
    loss, metrics = api.loss(params, batch)          # train step core
    logits = api.forward(params, batch)              # prefill
    caches = api.cache_init(batch_size, max_len)     # decode state
    logits, caches = api.decode_step(params, token, caches)
    batch = api.make_batch(rng, B, N)                # real arrays (tests)
    specs = api.batch_specs(B, N)                    # ShapeDtypeStructs (dry-run)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec as _ed
from repro.models import pointcloud as _pc
from repro.models import transformer as _tf
from repro.models import vlm as _vlm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    mcfg: Any
    init: Callable
    loss: Callable
    forward: Callable
    make_batch: Callable
    batch_specs: Callable
    cache_init: Callable | None = None
    cache_specs: Callable | None = None
    decode_step: Callable | None = None
    # paged continuous-batching decode (LM families; serving/paged_cache.py
    # owns the host-side tables these consume)
    paged_cache_init: Callable | None = None
    paged_decode_step: Callable | None = None
    cache_reset_slot: Callable | None = None
    cache_copy_block: Callable | None = None
    has_recurrent_state: bool = False

    @property
    def has_decoder(self) -> bool:
        return self.decode_step is not None

    @property
    def has_paged_decoder(self) -> bool:
        return self.paged_decode_step is not None


def _lm_api(mcfg) -> ModelAPI:
    def make_batch(rng, B, N):
        toks = rng.integers(0, mcfg.vocab_size, (B, N), dtype=np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    def batch_specs(B, N):
        t = jax.ShapeDtypeStruct((B, N), jnp.int32)
        return {"tokens": t, "labels": t}

    def cache_init(B, S, dtype=jnp.bfloat16):
        return _tf.lm_cache_init(mcfg, B, S, dtype)

    def cache_specs(B, S, dtype=jnp.bfloat16):
        return jax.eval_shape(lambda: cache_init(B, S, dtype))

    return ModelAPI(
        mcfg=mcfg,
        init=lambda key: _tf.lm_init(key, mcfg),
        loss=lambda p, b: _tf.lm_loss(p, b, mcfg=mcfg),
        forward=lambda p, b: _tf.lm_apply(p, b["tokens"], mcfg=mcfg)[0],
        make_batch=make_batch,
        batch_specs=batch_specs,
        cache_init=cache_init,
        cache_specs=cache_specs,
        decode_step=lambda p, tok, c: _tf.lm_decode_step(p, tok, c, mcfg=mcfg),
        paged_cache_init=lambda B, num_blocks, page, dtype=jnp.bfloat16:
            _tf.lm_paged_cache_init(mcfg, B, num_blocks, page, dtype),
        paged_decode_step=lambda p, tok, c, table, lengths, page:
            _tf.lm_paged_decode_step(p, tok, c, table, lengths, mcfg=mcfg,
                                     page=page),
        cache_reset_slot=lambda c, slot: _tf.lm_paged_cache_reset_slot(
            mcfg, c, slot),
        cache_copy_block=lambda c, src, dst, page: _tf.lm_paged_cache_copy_block(
            mcfg, c, src, dst, page=page),
        has_recurrent_state=_tf.lm_has_recurrent_state(mcfg),
    )


def _vlm_api(mcfg) -> ModelAPI:
    dv = mcfg.d_frontend
    SI = mcfg.vision_tokens

    def make_batch(rng, B, N):
        St = N - SI
        toks = rng.integers(0, mcfg.vocab_size, (B, St), dtype=np.int32)
        pe = rng.standard_normal((B, SI, dv), dtype=np.float32)
        return {"tokens": jnp.asarray(toks),
                "patch_embeds": jnp.asarray(pe, dtype=mcfg.cdtype()),
                "labels": jnp.asarray(toks)}

    def batch_specs(B, N):
        St = N - SI
        return {"tokens": jax.ShapeDtypeStruct((B, St), jnp.int32),
                "patch_embeds": jax.ShapeDtypeStruct((B, SI, dv), mcfg.cdtype()),
                "labels": jax.ShapeDtypeStruct((B, St), jnp.int32)}

    def cache_init(B, S, dtype=jnp.bfloat16):
        return _tf.lm_cache_init(mcfg, B, S, dtype)

    return ModelAPI(
        mcfg=mcfg,
        init=lambda key: _vlm.vlm_init(key, mcfg),
        loss=lambda p, b: _vlm.vlm_loss(p, b, mcfg=mcfg),
        forward=lambda p, b: _vlm.vlm_apply(p, b["tokens"], b["patch_embeds"],
                                            mcfg=mcfg)[0],
        make_batch=make_batch,
        batch_specs=batch_specs,
        cache_init=cache_init,
        cache_specs=lambda B, S, dtype=jnp.bfloat16: jax.eval_shape(
            lambda: cache_init(B, S, dtype)),
        # decode runs on the LM backbone (vision is prefill-only)
        decode_step=lambda p, tok, c: _tf.lm_decode_step(p["lm"], tok, c, mcfg=mcfg),
    )


def _encdec_api(mcfg) -> ModelAPI:
    df = mcfg.d_frontend

    def make_batch(rng, B, N):
        Sd = max(N // mcfg.dec_ratio, 16)
        fr = rng.standard_normal((B, N, df), dtype=np.float32)
        toks = rng.integers(0, mcfg.vocab_size, (B, Sd), dtype=np.int32)
        return {"frames": jnp.asarray(fr, dtype=mcfg.cdtype()),
                "dec_tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    def batch_specs(B, N):
        Sd = max(N // mcfg.dec_ratio, 16)
        return {"frames": jax.ShapeDtypeStruct((B, N, df), mcfg.cdtype()),
                "dec_tokens": jax.ShapeDtypeStruct((B, Sd), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, Sd), jnp.int32)}

    def cache_specs(B, S, dtype=jnp.bfloat16):
        """Decoder self-attn caches (len S) + cross-attn memory K/V (len S)."""
        def build():
            mem = jnp.zeros((B, S, mcfg.d_model), mcfg.cdtype())
            p = jax.eval_shape(lambda k: _ed.encdec_init(k, mcfg),
                               jax.random.PRNGKey(0))
            # cache_init only needs shapes of dec_layers weights; build zeros
            pz = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p)
            return _ed.encdec_cache_init(pz, mem, mcfg=mcfg, batch=B,
                                         max_len=S, dtype=dtype)
        return jax.eval_shape(build)

    def cache_init(B, S, dtype=jnp.bfloat16, params=None, memory=None):
        assert params is not None and memory is not None
        return _ed.encdec_cache_init(params, memory, mcfg=mcfg, batch=B,
                                     max_len=S, dtype=dtype)

    return ModelAPI(
        mcfg=mcfg,
        init=lambda key: _ed.encdec_init(key, mcfg),
        loss=lambda p, b: _ed.encdec_loss(p, b, mcfg=mcfg),
        forward=lambda p, b: _ed.decode_train(
            p, b["dec_tokens"], _ed.encode(p, b["frames"], mcfg=mcfg), mcfg=mcfg),
        make_batch=make_batch,
        batch_specs=batch_specs,
        cache_init=cache_init,
        cache_specs=cache_specs,
        decode_step=lambda p, tok, c: _ed.encdec_decode_step(p, tok, c, mcfg=mcfg),
    )


def _pc_api(mcfg) -> ModelAPI:
    def make_batch(rng, B, N):
        feats = rng.standard_normal((B, N, mcfg.in_dim), dtype=np.float32)
        tgt = rng.standard_normal((B, N, mcfg.out_dim), dtype=np.float32)
        mask = np.ones((B, N), bool)
        return {"feats": jnp.asarray(feats), "target": jnp.asarray(tgt),
                "mask": jnp.asarray(mask)}

    def batch_specs(B, N):
        return {"feats": jax.ShapeDtypeStruct((B, N, mcfg.in_dim), jnp.float32),
                "target": jax.ShapeDtypeStruct((B, N, mcfg.out_dim), jnp.float32),
                "mask": jax.ShapeDtypeStruct((B, N), jnp.bool_)}

    return ModelAPI(
        mcfg=mcfg,
        init=lambda key: _pc.pc_init(key, mcfg),
        loss=lambda p, b: _pc.pc_loss(p, b, mcfg=mcfg),
        forward=lambda p, b: _pc.pc_apply(p, b["feats"], mcfg=mcfg,
                                          mask=b.get("mask"),
                                          offsets=b.get("offsets")),
        make_batch=make_batch,
        batch_specs=batch_specs,
    )


def model_api(mcfg) -> ModelAPI:
    if mcfg.family in ("dense", "moe", "ssm", "hybrid"):
        return _lm_api(mcfg)
    if mcfg.family == "vlm":
        return _vlm_api(mcfg)
    if mcfg.family == "audio":
        return _encdec_api(mcfg)
    if mcfg.family == "pointcloud":
        return _pc_api(mcfg)
    raise ValueError(f"unknown family {mcfg.family}")
