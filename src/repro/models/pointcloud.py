"""The paper's model: 18 × [RMSNorm → BSA → RMSNorm → SwiGLU] on ball-ordered
point clouds, MSE regression head (airflow pressure / stress field).

The attention backend is switchable (``bsa`` | ``full`` | ``erwin``) to
reproduce Tables 1–3.  Inputs arrive ball-ordered (data pipeline applies the
ball-tree permutation) with a validity mask for padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.layers.nn import dense, dense_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from repro.models.attention_layer import attention_layer_apply, attention_layer_init


def pc_init(key, mcfg) -> dict:
    pd = mcfg.pdtype()
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: _layer_init(k, mcfg, pd))(
        jax.random.split(kl, mcfg.n_layers))
    return {
        "embed": dense_init(ke, mcfg.in_dim, mcfg.d_model, param_dtype=pd, bias=True),
        "layers": layers,
        "final_norm": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "head": dense_init(kh, mcfg.d_model, mcfg.out_dim, param_dtype=pd,
                           scale=0.02, bias=True),
    }


def _layer_init(key, mcfg, pd):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "attn": attention_layer_init(k1, mcfg, param_dtype=pd),
        "norm2": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "ffn": swiglu_init(k2, mcfg.d_model, mcfg.d_ff, param_dtype=pd),
    }


def pc_apply(params, feats, *, mcfg, mask=None, erwin_level_of=None,
             offsets=None):
    """feats: (B, N, in_dim) ball-ordered; mask: (B, N).  → (B, N, out_dim).

    ``offsets`` (S+1,) int32 selects the packed-varlen layout (docs/varlen.md):
    feats is then ONE packed row (B=1) of concatenated samples and every
    attention layer runs segment-isolated with no dummy batch slots."""
    cdt = mcfg.cdtype()
    x = dense(params["embed"], feats.astype(cdt))
    x = constrain(x, "batch", "seq_res", "d_model")

    def layer(lp, x, level):
        h = rmsnorm(lp["norm1"], x, mcfg.norm_eps)
        h = attention_layer_apply(lp["attn"], h, mcfg=mcfg, causal=False,
                                  mask=mask, positions=None, rope=False,
                                  erwin_level=level, offsets=offsets)
        x = x + h
        h = rmsnorm(lp["norm2"], x, mcfg.norm_eps)
        x = x + swiglu(lp["ffn"], h)
        return constrain(x, "batch", "seq_res", "d_model")

    if mcfg.attention == "erwin" and erwin_level_of is None:
        # Erwin's coarsen/refine cycle: levels 0,1,2,1,0,...
        cyc = [0, 1, 2, 1]
        erwin_level_of = lambda i: cyc[i % len(cyc)]

    if erwin_level_of is not None:
        # per-layer levels differ → unrolled loop (baseline only, 18 layers)
        for i in range(mcfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            x = layer(lp, x, erwin_level_of(i))
    else:
        fn = functools.partial(layer, level=0)
        if mcfg.remat:
            fn = jax.checkpoint(fn)
        def body(x, lp):
            return fn(lp, x), None
        x, _ = jax.lax.scan(body, x, params["layers"])

    x = rmsnorm(params["final_norm"], x, mcfg.norm_eps)
    return dense(params["head"], x).astype(jnp.float32)


def pc_loss(params, batch, *, mcfg):
    """batch: {feats (B,N,F), target (B,N,out_dim), mask (B,N)} → MSE.
    An optional ``offsets`` key selects the packed-varlen layout."""
    pred = pc_apply(params, batch["feats"], mcfg=mcfg, mask=batch.get("mask"),
                    offsets=batch.get("offsets"))
    err = (pred - batch["target"].astype(jnp.float32)) ** 2
    m = batch.get("mask")
    if m is not None:
        err = jnp.where(m[..., None], err, 0.0)
        denom = jnp.maximum(m.sum() * mcfg.out_dim, 1)
    else:
        denom = err.size
    loss = err.sum() / denom
    return loss, {"mse": loss}
