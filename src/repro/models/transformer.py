"""Decoder-only LM backbone covering dense / MoE / SSM / hybrid families.

Layers are organised in PERIODS: a period is the smallest repeating pattern
of (mixer, ffn) pairs — e.g. jamba's 8-layer [7×mamba + 1×attn, MoE every
2nd] pattern, phi-3.5-MoE's 1-layer [attn, moe], tinyllama's [attn, dense].
Parameters are stacked over periods and the forward pass is a single
``jax.lax.scan`` over the stack → compact HLO (essential for 512-way SPMD
compiles) and a natural remat boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.layers.losses import masked_mean_nll
from repro.layers.nn import (
    dense, dense_init, embed, embed_init, rmsnorm, rmsnorm_init, swiglu,
    swiglu_init, unembed,
)
from repro.models.attention_layer import (
    attention_cache_init,
    attention_layer_apply,
    attention_layer_decode,
    attention_layer_decode_paged,
    attention_layer_init,
    attention_paged_cache_init,
)
from repro.models.mamba2 import (
    mamba2_apply,
    mamba2_cache_init,
    mamba2_decode,
    mamba2_init,
)
from repro.models.moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

def layer_spec(mcfg) -> list[tuple[str, str]]:
    """[(mixer, ffn)] for ONE period."""
    if mcfg.attn_period:                      # hybrid (jamba): 1 attn per period
        P = mcfg.attn_period
        mixers = ["mamba"] * P
        mixers[P // 2] = "attn"
    elif mcfg.family == "ssm":
        P = max(mcfg.moe_period, 1)
        mixers = ["mamba"] * P
    else:
        P = max(mcfg.moe_period, 1) if mcfg.moe else 1
        mixers = ["attn"] * P
    ffns = []
    for i in range(P):
        if mcfg.d_ff == 0 and not mcfg.moe:
            ffns.append("none")
        elif mcfg.moe and (i % max(mcfg.moe_period, 1) == max(mcfg.moe_period, 1) - 1):
            ffns.append("moe")
        else:
            ffns.append("dense")
    return list(zip(mixers, ffns))


def n_periods(mcfg) -> int:
    P = len(layer_spec(mcfg))
    assert mcfg.n_layers % P == 0, (mcfg.n_layers, P)
    return mcfg.n_layers // P


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_one_period(key, mcfg, param_dtype):
    spec = layer_spec(mcfg)
    p = {}
    for i, (mixer, ffn) in enumerate(spec):
        k1, k2, key = jax.random.split(key, 3)
        lp = {"norm1": rmsnorm_init(mcfg.d_model, param_dtype=param_dtype)}
        if mixer == "attn":
            lp["attn"] = attention_layer_init(k1, mcfg, param_dtype=param_dtype)
        else:
            lp["mamba"] = mamba2_init(k1, mcfg, param_dtype=param_dtype)
        if ffn != "none":
            lp["norm2"] = rmsnorm_init(mcfg.d_model, param_dtype=param_dtype)
            if ffn == "moe":
                lp["moe"] = moe_init(k2, mcfg, param_dtype=param_dtype)
            else:
                lp["ffn"] = swiglu_init(k2, mcfg.d_model, mcfg.d_ff,
                                        param_dtype=param_dtype)
        p[f"pos{i}"] = lp
    return p


def lm_init(key, mcfg) -> dict:
    pd = mcfg.pdtype()
    ke, kl, kh = jax.random.split(key, 3)
    NP = n_periods(mcfg)
    layers = jax.vmap(lambda k: _init_one_period(k, mcfg, pd))(
        jax.random.split(kl, NP))
    params = {
        "embed": embed_init(ke, mcfg.vocab_size, mcfg.d_model, param_dtype=pd),
        "layers": layers,
        "final_norm": rmsnorm_init(mcfg.d_model, param_dtype=pd),
    }
    if not mcfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, mcfg.d_model, mcfg.vocab_size,
                                       param_dtype=pd, scale=0.02)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _period_apply(pp, x, *, mcfg, mask, positions, causal=True):
    spec = layer_spec(mcfg)
    aux_loss = jnp.zeros((), jnp.float32)
    for i, (mixer, ffn) in enumerate(spec):
        lp = pp[f"pos{i}"]
        h = rmsnorm(lp["norm1"], x, mcfg.norm_eps)
        if mixer == "attn":
            h = attention_layer_apply(lp["attn"], h, mcfg=mcfg, causal=causal,
                                      mask=mask, positions=positions)
        else:
            h = mamba2_apply(lp["mamba"], h, mcfg)
        x = x + h
        x = constrain(x, "batch", "seq_res", "d_model")
        if ffn != "none":
            h = rmsnorm(lp["norm2"], x, mcfg.norm_eps)
            if ffn == "moe":
                h, aux = moe_apply(lp["moe"], h, mcfg)
                aux_loss = aux_loss + aux["aux_loss"]
            else:
                h = swiglu(lp["ffn"], h)
            x = x + h
            x = constrain(x, "batch", "seq_res", "d_model")
    return x, aux_loss


def lm_apply(params, tokens=None, *, mcfg, inputs_embeds=None, mask=None,
             positions=None, causal: bool = True, return_hidden: bool = False):
    """tokens: (B, N) int32 (or inputs_embeds (B, N, d)).  Returns
    (logits (B,N,V) fp32, aux_loss)."""
    cdt = mcfg.cdtype()
    if inputs_embeds is None:
        x = embed(params["embed"], tokens, dtype=cdt)
    else:
        x = inputs_embeds.astype(cdt)
    B, N, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], (B, N))
    x = constrain(x, "batch", "seq_res", "d_model")

    period = functools.partial(_period_apply, mcfg=mcfg, mask=mask,
                               positions=positions, causal=causal)
    if mcfg.remat:
        period = jax.checkpoint(period, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, pp):
        x, aux = carry
        x, aux_p = period(pp, x)
        return (x, aux + aux_p), None

    (x, aux_loss), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    x = rmsnorm(params["final_norm"], x, mcfg.norm_eps)
    if return_hidden:
        return x, aux_loss
    if mcfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["lm_head"], x)
    logits = constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")
    return logits, aux_loss


def lm_loss(params, batch, *, mcfg):
    """batch: {tokens (B,N), labels (B,N), [loss_mask (B,N)]}."""
    logits, aux_loss = lm_apply(params, batch["tokens"], mcfg=mcfg,
                                inputs_embeds=batch.get("inputs_embeds"))
    loss = masked_mean_nll(logits, batch["labels"], batch.get("loss_mask"))
    return loss + 0.01 * aux_loss, {"loss": loss, "aux_loss": aux_loss}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _period_cache_init(mcfg, batch, max_len, dtype):
    spec = layer_spec(mcfg)
    c = {}
    for i, (mixer, _) in enumerate(spec):
        if mixer == "attn":
            c[f"pos{i}"] = attention_cache_init(mcfg, batch, max_len, dtype)
        else:
            c[f"pos{i}"] = mamba2_cache_init(mcfg, batch, dtype)
    return c


def lm_cache_init(mcfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    NP = n_periods(mcfg)
    one = _period_cache_init(mcfg, batch, max_len, dtype)
    return jax.tree.map(lambda t: jnp.zeros((NP,) + t.shape, t.dtype), one)


def _period_decode(pp, pc, x1, *, mcfg):
    spec = layer_spec(mcfg)
    new_c = {}
    for i, (mixer, ffn) in enumerate(spec):
        lp = pp[f"pos{i}"]
        h = rmsnorm(lp["norm1"], x1, mcfg.norm_eps)
        if mixer == "attn":
            h, new_c[f"pos{i}"] = attention_layer_decode(lp["attn"], h, pc[f"pos{i}"],
                                                         mcfg=mcfg)
        else:
            h, new_c[f"pos{i}"] = mamba2_decode(lp["mamba"], h, pc[f"pos{i}"], mcfg)
        x1 = x1 + h
        if ffn != "none":
            h = rmsnorm(lp["norm2"], x1, mcfg.norm_eps)
            if ffn == "moe":
                h, _ = moe_apply(lp["moe"], h, mcfg)
            else:
                h = swiglu(lp["ffn"], h)
            x1 = x1 + h
    return x1, new_c


def lm_decode_step(params, token, caches, *, mcfg):
    """token: (B,) int32 → (logits (B, V), new_caches)."""
    cdt = mcfg.cdtype()
    x1 = embed(params["embed"], token[:, None], dtype=cdt)       # (B,1,d)

    def body(x1, inp):
        pp, pc = inp
        x1, new_pc = _period_decode(pp, pc, x1, mcfg=mcfg)
        return x1, new_pc

    x1, new_caches = jax.lax.scan(body, x1, (params["layers"], caches))
    x1 = rmsnorm(params["final_norm"], x1, mcfg.norm_eps)
    if mcfg.tie_embeddings:
        logits = unembed(params["embed"], x1)
    else:
        logits = dense(params["lm_head"], x1)
    return logits[:, 0].astype(jnp.float32), new_caches


# ---------------------------------------------------------------------------
# Paged decode (continuous batching — serving/paged_cache.py owns the tables)
# ---------------------------------------------------------------------------

def lm_paged_cache_init(mcfg, batch: int, num_blocks: int, page: int,
                        dtype=jnp.bfloat16):
    """Per-period caches with PAGED attention pools.

    Attention layers get flat block pools (shared across slots through one
    host-side block table — the same block ids index every layer's pool);
    mamba layers keep per-slot recurrent rows (B, ·) — constant-size state
    needs no paging, but DOES need :func:`lm_paged_cache_reset_slot` on
    admission.  Stacked over periods like :func:`lm_cache_init`."""
    spec = layer_spec(mcfg)
    one = {}
    for i, (mixer, _) in enumerate(spec):
        if mixer == "attn":
            one[f"pos{i}"] = attention_paged_cache_init(mcfg, num_blocks, page,
                                                        dtype)
        else:
            one[f"pos{i}"] = mamba2_cache_init(mcfg, batch, dtype)
    NP = n_periods(mcfg)
    return jax.tree.map(lambda t: jnp.zeros((NP,) + t.shape, t.dtype), one)


def lm_paged_decode_step(params, token, caches, table, lengths, *, mcfg,
                         page: int):
    """token: (B,) int32; table (B, n_pages); lengths (B,) per-slot positions
    → (logits (B, V), new_caches).  Lengths are NOT advanced (host-owned)."""
    cdt = mcfg.cdtype()
    x1 = embed(params["embed"], token[:, None], dtype=cdt)       # (B,1,d)
    spec = layer_spec(mcfg)

    def body(x1, inp):
        pp, pc = inp
        new_c = {}
        for i, (mixer, ffn) in enumerate(spec):
            lp = pp[f"pos{i}"]
            h = rmsnorm(lp["norm1"], x1, mcfg.norm_eps)
            if mixer == "attn":
                h, new_c[f"pos{i}"] = attention_layer_decode_paged(
                    lp["attn"], h, pc[f"pos{i}"], table, lengths,
                    mcfg=mcfg, page=page)
            else:
                h, new_c[f"pos{i}"] = mamba2_decode(lp["mamba"], h,
                                                    pc[f"pos{i}"], mcfg)
            x1 = x1 + h
            if ffn != "none":
                h = rmsnorm(lp["norm2"], x1, mcfg.norm_eps)
                if ffn == "moe":
                    h, _ = moe_apply(lp["moe"], h, mcfg)
                else:
                    h = swiglu(lp["ffn"], h)
                x1 = x1 + h
        return x1, new_c

    x1, new_caches = jax.lax.scan(body, x1, (params["layers"], caches))
    x1 = rmsnorm(params["final_norm"], x1, mcfg.norm_eps)
    if mcfg.tie_embeddings:
        logits = unembed(params["embed"], x1)
    else:
        logits = dense(params["lm_head"], x1)
    return logits[:, 0].astype(jnp.float32), new_caches


def lm_has_recurrent_state(mcfg) -> bool:
    """True when any mixer carries UNPAGED per-slot state (mamba): such
    state must be zeroed on admission and blocks prefix-block reuse (a
    cached KV page can't restore a recurrent hidden state)."""
    return any(mixer != "attn" for mixer, _ in layer_spec(mcfg))


def lm_paged_cache_reset_slot(mcfg, caches, slot):
    """Zero slot-local recurrent (mamba) state on request admission.

    Attention pools need no reset: stale rows in freshly allocated blocks
    are never read (every read is masked to positions ≤ the slot's length,
    all of which get written first).  No-op (returns ``caches``) for
    attention-only stacks."""
    if not lm_has_recurrent_state(mcfg):
        return caches
    spec = layer_spec(mcfg)
    new = dict(caches)
    for i, (mixer, _) in enumerate(spec):
        if mixer != "attn":
            new[f"pos{i}"] = jax.tree.map(
                lambda t: t.at[:, slot].set(jnp.zeros_like(t[:, slot])),
                caches[f"pos{i}"])
    return new


def lm_paged_cache_copy_block(mcfg, caches, src, dst, *, page: int):
    """Copy pool block ``src`` → ``dst`` in EVERY attention layer's pools
    (token rows and φ-compressed rows) — the device half of copy-on-write.
    ``src``/``dst`` may be traced scalars (the engine jits this once)."""
    spec = layer_spec(mcfg)
    new = dict(caches)
    for i, (mixer, _) in enumerate(spec):
        if mixer != "attn":
            continue
        c = dict(caches[f"pos{i}"])
        for key in c:
            rows = page if key in ("k", "v") else page // mcfg.bsa.cmp_block
            blk = jax.lax.dynamic_slice_in_dim(c[key], src * rows, rows, axis=1)
            c[key] = jax.lax.dynamic_update_slice_in_dim(c[key], blk,
                                                         dst * rows, axis=1)
        new[f"pos{i}"] = c
    return new


def lm_prefill(params, tokens, caches, *, mcfg):
    """Teacher-forced prefill: run the full sequence through the TRAIN path
    once for logits, then replay tokens through decode to warm the cache.
    (Used by serving; for BSA the decode path is cache-exact so serving uses
    decode replay only when needed.)"""
    logits, _ = lm_apply(params, tokens, mcfg=mcfg)
    return logits
