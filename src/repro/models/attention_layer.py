"""Attention layer: QKV/O projections + RoPE + attention dispatch.

One layer serves all model families; the attention MECHANISM (``bsa`` |
``full`` | ``erwin``, ``mcfg.attention``) and causality are chosen by the
caller, while the execution BACKEND (jnp / pallas / interpret / plug-in,
``mcfg.bsa.backend`` — see ``repro.core.backend``) is orthogonal and applies
to every mechanism.  Decode steps share the same projections and route
through ``core.nsa_causal_decode`` (sparse) or a dense cached path (full
attention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    bsa_attention,
    bsa_attention_varlen,
    bsa_init,
    erwin_attention,
    full_attention,
    init_decode_cache,
    init_paged_decode_cache,
    nsa_causal_attention,
    nsa_causal_decode,
    nsa_causal_decode_paged,
)
from repro.core.branches import repeat_kv, sdpa, mask_to_bias
from repro.layers.nn import dense, dense_init
from repro.layers.rope import apply_rope


def attention_layer_init(key, mcfg, *, param_dtype) -> dict:
    d = mcfg.d_model
    hd = mcfg.resolved_head_dim
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, d, mcfg.n_heads * hd, param_dtype=param_dtype),
        "wk": dense_init(kk, d, mcfg.n_kv_heads * hd, param_dtype=param_dtype),
        "wv": dense_init(kv, d, mcfg.n_kv_heads * hd, param_dtype=param_dtype),
        "wo": dense_init(ko, mcfg.n_heads * hd, d, param_dtype=param_dtype),
    }
    if mcfg.attention == "bsa":
        init_fn = bsa_init  # same param structure as nsa_init
        p["bsa"] = init_fn(kb, mcfg.bsa, n_heads=mcfg.n_heads,
                           n_kv_heads=mcfg.n_kv_heads, head_dim=hd,
                           d_model=d, param_dtype=param_dtype)
    return p


def _project(p, x, mcfg, positions=None, rope: bool = True):
    B, N, _ = x.shape
    hd = mcfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, N, mcfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, N, mcfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, N, mcfg.n_kv_heads, hd)
    if rope and positions is not None:
        q = apply_rope(q, positions, mcfg.rope_theta)
        k = apply_rope(k, positions, mcfg.rope_theta)
    return q, k, v


def attention_layer_apply(p, x, *, mcfg, causal: bool, mask=None,
                          positions=None, rope: bool = True,
                          erwin_level: int = 0, offsets=None):
    """Full-sequence forward.  x: (B, N, d_model) → (B, N, d_model).

    ``offsets`` (S+1,) int32 switches the non-causal BSA path to the
    PACKED-VARLEN layout (docs/varlen.md): x must then be a single packed
    row (B == 1) whose samples are concatenated back-to-back at ball-size
    boundaries, and ``mask``'s row marks real tokens.  Other mechanisms
    don't support it (yet) and raise.
    """
    B, N, _ = x.shape
    q, k, v = _project(p, x, mcfg, positions, rope)
    if offsets is not None:
        if mcfg.attention != "bsa" or causal:
            raise NotImplementedError(
                "packed-varlen offsets are only supported by non-causal BSA "
                f"(got attention={mcfg.attention!r}, causal={causal})")
        if B != 1:
            raise ValueError(
                f"packed-varlen input must be a single packed row, got B={B}")
        out = bsa_attention_varlen(
            p["bsa"], q[0], k[0], v[0], cfg=mcfg.bsa, offsets=offsets,
            mask=None if mask is None else mask[0], x=x[0])[None]
    elif mcfg.attention == "bsa":
        if causal:
            out = nsa_causal_attention(p["bsa"], q, k, v, cfg=mcfg.bsa,
                                       mask=mask, x=x)
        else:
            out = bsa_attention(p["bsa"], q, k, v, cfg=mcfg.bsa, mask=mask, x=x)
    elif mcfg.attention == "erwin":
        out = erwin_attention(q, k, v, ball_size=mcfg.bsa.ball_size,
                              level=erwin_level, mask=mask,
                              backend=mcfg.bsa.backend)
    else:
        out = full_attention(q, k, v, mask=mask, causal=causal,
                             backend=mcfg.bsa.backend)
    out = out.reshape(B, N, mcfg.n_heads * mcfg.resolved_head_dim)
    return dense(p["wo"], out)


def cross_attention_apply(p, x, memory_kv, *, mcfg, mem_mask=None):
    """Cross-attention with precomputed memory K/V: (B, L, Hkv, D) pair."""
    B, N, _ = x.shape
    hd = mcfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, N, mcfg.n_heads, hd)
    mk, mv = memory_kv
    out = full_attention(q, mk, mv, mask=mem_mask, causal=False,
                         backend=mcfg.bsa.backend)
    return dense(p["wo"], out.reshape(B, N, mcfg.n_heads * hd))


def memory_kv(p, memory, *, mcfg):
    """Precompute cross-attention K/V from encoder output (B, L, d)."""
    B, L, _ = memory.shape
    hd = mcfg.resolved_head_dim
    mk = dense(p["wk"], memory).reshape(B, L, mcfg.n_kv_heads, hd)
    mv = dense(p["wv"], memory).reshape(B, L, mcfg.n_kv_heads, hd)
    return mk, mv


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def attention_cache_init(mcfg, batch: int, max_len: int, dtype) -> dict:
    hd = mcfg.resolved_head_dim
    if mcfg.attention == "bsa":
        return init_decode_cache(batch, max_len, mcfg.n_kv_heads, hd,
                                 mcfg.bsa, dtype=dtype)
    return {
        "k": jnp.zeros((batch, max_len, mcfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, mcfg.n_kv_heads, hd), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def attention_paged_cache_init(mcfg, num_blocks: int, page: int, dtype) -> dict:
    """Flat paged KV pools for one attention layer (+1 trash block).

    BSA layers carry token + φ-compressed pools (``init_paged_decode_cache``);
    full attention carries token pools only.  Block ids are SHARED across
    layers: every layer's pool has the same block layout, so one host-side
    block table serves the whole stack."""
    hd = mcfg.resolved_head_dim
    if mcfg.attention == "bsa":
        return init_paged_decode_cache(num_blocks, page, mcfg.n_kv_heads, hd,
                                       mcfg.bsa, dtype=dtype)
    R = (num_blocks + 1) * page
    return {
        "k": jnp.zeros((R, mcfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((R, mcfg.n_kv_heads, hd), dtype),
    }


def attention_layer_decode_paged(p, x1, cache, table, lengths, *, mcfg,
                                 page: int, rope: bool = True):
    """One-token decode against paged pools with PER-SLOT lengths.

    x1: (B, 1, d); ``table`` (B, n_pages) int32 block table; ``lengths``
    (B,) int32 per-slot positions (RoPE rotates each slot's query/key by its
    OWN position — the per-slot generalisation of the lockstep scalar).
    """
    B = x1.shape[0]
    pos = lengths[:, None].astype(jnp.int32)                         # (B,1)
    q, k, v = _project(p, x1, mcfg, pos if rope else None, rope)
    if mcfg.attention == "bsa":
        out, cache = nsa_causal_decode_paged(p["bsa"], q, k, v, cache, table,
                                             lengths, cfg=mcfg.bsa, page=page,
                                             x1=x1)
    else:
        n_pages = table.shape[1]
        capacity = n_pages * page
        wblk = jnp.take_along_axis(table, (lengths // page)[:, None], axis=1)
        wrow = wblk[:, 0] * page + lengths % page                    # (B,)
        kc = cache["k"].at[wrow].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[wrow].set(v[:, 0].astype(cache["v"].dtype))
        apos = jnp.broadcast_to(jnp.arange(capacity)[None], (B, capacity))
        blk = jnp.take_along_axis(table, apos // page, axis=1)
        rows = blk * page + apos % page                              # (B,cap)
        k_all = kc[rows]                                             # (B,cap,Hkv,D)
        v_all = vc[rows]
        valid = apos <= lengths[:, None]
        rep = mcfg.n_heads // mcfg.n_kv_heads
        out = sdpa(q.transpose(0, 2, 1, 3),
                   repeat_kv(k_all.astype(q.dtype), rep).transpose(0, 2, 1, 3),
                   repeat_kv(v_all.astype(q.dtype), rep).transpose(0, 2, 1, 3),
                   mask_to_bias(valid[:, None, None, :])).transpose(0, 2, 1, 3)
        cache = {"k": kc, "v": vc}
    out = out.reshape(B, 1, mcfg.n_heads * mcfg.resolved_head_dim)
    return dense(p["wo"], out), cache


def attention_layer_decode(p, x1, cache, *, mcfg, rope: bool = True):
    """One-token decode.  x1: (B, 1, d) → (B, 1, d), updated cache."""
    B = x1.shape[0]
    t = cache["length"]
    pos = jnp.full((B, 1), t, jnp.int32)
    q, k, v = _project(p, x1, mcfg, pos if rope else None, rope)
    if mcfg.attention == "bsa":
        out, cache = nsa_causal_decode(p["bsa"], q, k, v, cache,
                                       cfg=mcfg.bsa, x1=x1)
    else:
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, t, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, t, 0, 0))
        S = kc.shape[1]
        valid = jnp.arange(S)[None, None, None, :] <= t
        rep = mcfg.n_heads // mcfg.n_kv_heads
        out = sdpa(q.transpose(0, 2, 1, 3),
                   repeat_kv(kc.astype(q.dtype), rep).transpose(0, 2, 1, 3),
                   repeat_kv(vc.astype(q.dtype), rep).transpose(0, 2, 1, 3),
                   mask_to_bias(valid)).transpose(0, 2, 1, 3)
        cache = {"k": kc, "v": vc, "length": t + 1}
    out = out.reshape(B, 1, mcfg.n_heads * mcfg.resolved_head_dim)
    return dense(p["wo"], out), cache
