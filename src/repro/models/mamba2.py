"""Mamba-2 (SSD — state-space duality) mixer layer.

Chunked SSD algorithm (Dao & Gu 2024): within-chunk quadratic (attention-
like) term + inter-chunk linear state recurrence, both expressed with
einsums and one ``lax.scan`` over chunks.  A single-step recurrent decode
path shares the parameters (train ≡ decode is unit-tested).

The paper's BSA technique is attention-specific; this arch runs WITHOUT it
(DESIGN §Arch-applicability) — SSD is itself sub-quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.layers.nn import dense, dense_init, rmsnorm, rmsnorm_init

CHUNK = 128


def _dims(mcfg):
    d_inner = mcfg.ssm_expand * mcfg.d_model
    H = d_inner // mcfg.ssm_head_dim
    return d_inner, H, mcfg.ssm_head_dim, mcfg.ssm_state


def mamba2_init(key, mcfg, *, param_dtype) -> dict:
    d = mcfg.d_model
    d_inner, H, P, Ns = _dims(mcfg)
    conv_ch = d_inner + 2 * Ns
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * Ns + H          # z, x, B, C, dt
    return {
        "in_proj": dense_init(k1, d, d_proj, param_dtype=param_dtype),
        "conv_w": (jax.random.normal(k2, (mcfg.ssm_conv, conv_ch), jnp.float32)
                   * 0.1).astype(param_dtype),
        "conv_b": jnp.zeros((conv_ch,), param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(param_dtype),
        "D": jnp.ones((H,), param_dtype),
        "dt_bias": jnp.zeros((H,), param_dtype),
        "norm": rmsnorm_init(d_inner, param_dtype=param_dtype),
        "out_proj": dense_init(k3, d_inner, d, param_dtype=param_dtype),
    }


def _split_proj(proj, mcfg):
    d_inner, H, P, Ns = _dims(mcfg)
    z, xin, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + Ns, 2 * d_inner + 2 * Ns], axis=-1)
    return z, xin, Bm, Cm, dt


def _conv_train(p, u):
    """Causal depthwise conv (width ssm_conv).  u: (B, S, C)."""
    w = p["conv_w"].astype(u.dtype)                                # (W, C)
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :], (1,), "VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=u.shape[-1])
    return out + p["conv_b"].astype(u.dtype)


def mamba2_apply(p, x, mcfg):
    """x: (B, S, d_model) → (B, S, d_model).  Chunked SSD scan."""
    B, S, _ = x.shape
    d_inner, H, P, Ns = _dims(mcfg)
    Q = min(CHUNK, S)
    assert S % Q == 0
    nc = S // Q

    proj = dense(p["in_proj"], x)
    z, xin, Bm, Cm, dt = _split_proj(proj, mcfg)
    xBC = jax.nn.silu(_conv_train(p, jnp.concatenate([xin, Bm, Cm], -1))
                      .astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + Ns], axis=-1)
    xin = constrain(xin.reshape(B, S, H, P), "batch", "seq", "heads", "head_dim")

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                   # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    loga = dt * A[None, None, :]                                   # (B,S,H) = log decay
    dtx = xin.astype(jnp.float32) * dt[..., None]                  # (B,S,H,P)

    # chunk
    loga_c = loga.reshape(B, nc, Q, H)
    cs = jnp.cumsum(loga_c, axis=2)                                # inclusive
    dtx_c = dtx.reshape(B, nc, Q, H, P)
    B_c = Bm.reshape(B, nc, Q, Ns).astype(jnp.float32)
    C_c = Cm.reshape(B, nc, Q, Ns).astype(jnp.float32)

    # intra-chunk: y[i] = Σ_{j≤i} (C_i·B_j) exp(cs_i − cs_j) dtx_j
    CB = jnp.einsum("bcin,bcjn->bcij", C_c, B_c,
                    preferred_element_type=jnp.float32)            # (B,nc,Q,Q)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]              # (B,nc,Q,Q,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # mask in LOG space: exp of unmasked j>i entries overflows (grads → NaN)
    M = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", CB, M, dtx_c,
                         preferred_element_type=jnp.float32)

    # chunk out-states: S_c = Σ_j exp(cs_last − cs_j) B_j ⊗ dtx_j  (B,nc,H,Ns,P)
    decay_out = jnp.exp(cs[:, :, -1:, :] - cs)                     # (B,nc,Q,H)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", B_c, decay_out, dtx_c,
                     preferred_element_type=jnp.float32)
    A_tot = jnp.exp(cs[:, :, -1, :])                               # (B,nc,H)

    # inter-chunk recurrence (scan over chunks)
    def step(h, inp):
        a_tot, s_c = inp                                           # (B,H), (B,H,Ns,P)
        h_new = a_tot[..., None, None] * h + s_c
        return h_new, h                                            # emit ENTERING state
    h0 = jnp.zeros((B, H, Ns, P), jnp.float32)
    _, h_in = jax.lax.scan(step, h0,
                           (A_tot.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                           # (B,nc,H,Ns,P)

    # inter-chunk output: y_inter[i] = C_i · h_in · exp(cs_i)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", C_c, jnp.exp(cs), h_in,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                mcfg.norm_eps)
    return dense(p["out_proj"], y)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def mamba2_cache_init(mcfg, batch: int, dtype=jnp.float32) -> dict:
    d_inner, H, P, Ns = _dims(mcfg)
    conv_ch = d_inner + 2 * Ns
    return {
        "h": jnp.zeros((batch, H, Ns, P), jnp.float32),
        "conv": jnp.zeros((batch, mcfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba2_decode(p, x1, cache, mcfg):
    """x1: (B, 1, d_model) → (B, 1, d_model), updated cache."""
    B = x1.shape[0]
    d_inner, H, P, Ns = _dims(mcfg)
    proj = dense(p["in_proj"], x1)
    z, xin, Bm, Cm, dt = _split_proj(proj, mcfg)
    u = jnp.concatenate([xin, Bm, Cm], -1)                         # (B,1,C)
    win = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)  # (B,W,C)
    w = p["conv_w"].astype(u.dtype)
    xBC = jnp.einsum("bwc,wc->bc", win, w) + p["conv_b"].astype(u.dtype)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x1.dtype)
    xin, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + Ns], axis=-1)
    xin = xin.reshape(B, H, P)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(dt1 * A[None, :])                                  # (B,H)
    dtx = xin.astype(jnp.float32) * dt1[..., None]                 # (B,H,P)
    b_out = jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), dtx)
    h = a[..., None, None] * cache["h"] + b_out
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(x1.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype),
                mcfg.norm_eps)
    out = dense(p["out_proj"], y)
    return out, {"h": h, "conv": win[:, 1:]}
