"""Token-choice top-k Mixture-of-Experts FFN with capacity-bounded,
sort-based dispatch (GSPMD/EP-friendly: no host sync, fixed shapes).

Dispatch: flatten (token, choice) assignments, stable-sort by expert, rank
within expert segments, scatter into an (E, C, d) buffer (overflow dropped —
deterministic, position-in-sort order), run stacked SwiGLU experts with one
einsum each, gather back weighted by router probs.  The (E, C, d) buffer is
annotated with the ``experts`` logical axis so EP shards it across ``model``
and XLA inserts the all-to-alls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.layers.nn import dense, dense_init, swiglu, swiglu_init


def moe_init(key, mcfg, *, param_dtype) -> dict:
    d, E, dff = mcfg.d_model, mcfg.n_experts, mcfg.moe_d_ff
    Ep = max(mcfg.pad_experts_to, E)         # EP-alignment padding (inert)
    kr, ke, ks = jax.random.split(key, 3)
    scale = (2.0 / d) ** 0.5
    kg, ku, kd = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, d, E, param_dtype=param_dtype, scale=0.02),
        "w_gate": (jax.random.normal(kg, (Ep, d, dff), jnp.float32) * scale).astype(param_dtype),
        "w_up": (jax.random.normal(ku, (Ep, d, dff), jnp.float32) * scale).astype(param_dtype),
        "w_down": (jax.random.normal(kd, (Ep, dff, d), jnp.float32)
                   * (2.0 / dff) ** 0.5).astype(param_dtype),
    }
    if mcfg.n_shared_experts:
        p["shared"] = swiglu_init(ks, d, mcfg.n_shared_experts * dff,
                                  param_dtype=param_dtype)
    return p


def moe_apply(p, x, mcfg):
    """x: (B, S, d) → (B, S, d).  Returns (out, aux) with load-balance loss.

    Dispatch is PER BATCH ROW (vmapped): the batch dim is DP-sharded and the
    expert dim model-sharded, so the sort/scatter is device-local and the
    expert einsum contracts with no collective — token traffic to experts is
    the only cross-device movement (GSPMD all-to-all), never a full-buffer
    all-reduce."""
    B, S, d = x.shape
    E, k = mcfg.n_experts, mcfg.experts_per_token
    Ep = max(mcfg.pad_experts_to, E)

    logits = dense(p["router"], x).astype(jnp.float32)             # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                         # (B, S, k)
    w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)    # renormalise

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean((0, 1))                                        # (E,)
    onehot_counts = jnp.zeros((E,), jnp.int32).at[top_e.reshape(-1)].add(1)
    ce = onehot_counts.astype(jnp.float32) / (B * S * k)
    aux_loss = E * jnp.sum(me * ce)

    cap = int(mcfg.capacity_factor * S * k / E) or 1
    cap = min(max(cap, 4), S * k)
    rnd = 64 if cap >= 64 else 4
    cap = -(-cap // rnd) * rnd

    def row_dispatch(xr, er, wr):
        """xr: (S, d); er/wr: (S, k) → (buf (Ep,cap,d), e_sort, t_sort, w_sort, slot)."""
        e_flat = er.reshape(-1)
        t_flat = jnp.repeat(jnp.arange(S), k)
        w_flat = wr.reshape(-1)
        order = jnp.argsort(e_flat, stable=True)
        e_sort, t_sort, w_sort = e_flat[order], t_flat[order], w_flat[order]
        counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(S * k) - starts[e_sort]
        slot = jnp.where(rank < cap, rank, cap)                    # cap ⇒ drop
        buf = jnp.zeros((Ep, cap, d), xr.dtype)
        buf = buf.at[e_sort, slot].set(xr[t_sort], mode="drop")
        return buf, e_sort, t_sort, w_sort, slot

    buf, e_sort, t_sort, w_sort, slot = jax.vmap(row_dispatch)(x, top_e, w)
    buf = constrain(buf, "batch", "experts", "capacity", "d_model")

    # ---- experts: stacked SwiGLU (e over model, b over data — local) ----
    h_g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    h_u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype),
                     preferred_element_type=jnp.float32)
    h = jax.nn.silu(h_g) * h_u
    out_buf = jnp.einsum("becf,efd->becd", h.astype(x.dtype),
                         p["w_down"].astype(x.dtype),
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out_buf = constrain(out_buf, "batch", "experts", "capacity", "d_model")

    def row_combine(ob, e_sort, t_sort, w_sort, slot):
        y_sort = ob[e_sort, jnp.minimum(slot, cap - 1)]            # (S·k, d)
        y_sort = jnp.where((slot < cap)[:, None], y_sort, 0.0)
        return jnp.zeros((S, d), jnp.float32).at[t_sort].add(
            y_sort.astype(jnp.float32) * w_sort[:, None])

    y = jax.vmap(row_combine)(out_buf, e_sort, t_sort, w_sort, slot).astype(x.dtype)

    if "shared" in p:
        y = y + swiglu(p["shared"], x.reshape(B * S, d)).reshape(B, S, d)
    return y, {"aux_loss": aux_loss, "expert_counts": onehot_counts}
