"""Encoder-decoder backbone (seamless-m4t family).

Encoder: NON-CAUSAL BSA over stubbed modality frame embeddings — this is the
paper's true (point-set) form of BSA applied to 1-D frames.  Decoder: causal
BSA self-attention + full cross-attention + SwiGLU.  The audio frontend is a
stub per the assignment spec: ``input_specs()`` feeds precomputed frame
embeddings of dim ``d_frontend``.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.layers.losses import masked_mean_nll
from repro.layers.nn import (
    dense, dense_init, embed, embed_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init,
)
from repro.models.attention_layer import (
    attention_cache_init,
    attention_layer_apply,
    attention_layer_decode,
    attention_layer_init,
    cross_attention_apply,
    memory_kv,
)


def encdec_init(key, mcfg) -> dict:
    pd = mcfg.pdtype()
    kf, ke, kd, kt, kh = jax.random.split(key, 5)
    n_enc = mcfg.n_encoder_layers or mcfg.n_layers
    enc_layers = jax.vmap(lambda k: _enc_layer_init(k, mcfg, pd))(
        jax.random.split(ke, n_enc))
    dec_layers = jax.vmap(lambda k: _dec_layer_init(k, mcfg, pd))(
        jax.random.split(kd, mcfg.n_layers))
    return {
        "frontend_proj": dense_init(kf, mcfg.d_frontend or mcfg.d_model,
                                    mcfg.d_model, param_dtype=pd, bias=True),
        "enc_layers": enc_layers,
        "enc_norm": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "tok_embed": embed_init(kt, mcfg.vocab_size, mcfg.d_model, param_dtype=pd),
        "dec_layers": dec_layers,
        "dec_norm": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "lm_head": dense_init(kh, mcfg.d_model, mcfg.vocab_size,
                              param_dtype=pd, scale=0.02),
    }


def _enc_layer_init(key, mcfg, pd):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "attn": attention_layer_init(k1, mcfg, param_dtype=pd),
        "norm2": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "ffn": swiglu_init(k2, mcfg.d_model, mcfg.d_ff, param_dtype=pd),
    }


def _dec_layer_init(key, mcfg, pd):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "self_attn": attention_layer_init(k1, mcfg, param_dtype=pd),
        "norm_x": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "cross_attn": attention_layer_init(k2, mcfg, param_dtype=pd),
        "norm2": rmsnorm_init(mcfg.d_model, param_dtype=pd),
        "ffn": swiglu_init(k3, mcfg.d_model, mcfg.d_ff, param_dtype=pd),
    }


def encode(params, frames, *, mcfg, mask=None):
    """frames: (B, S_enc, d_frontend) → (B, S_enc, d_model)."""
    cdt = mcfg.cdtype()
    x = dense(params["frontend_proj"], frames.astype(cdt))
    x = constrain(x, "batch", "seq_res", "d_model")

    def layer(lp, x):
        h = rmsnorm(lp["norm1"], x, mcfg.norm_eps)
        h = attention_layer_apply(lp["attn"], h, mcfg=mcfg, causal=False,
                                  mask=mask, rope=False)
        x = x + h
        h = rmsnorm(lp["norm2"], x, mcfg.norm_eps)
        return constrain(x + swiglu(lp["ffn"], h), "batch", "seq", "d_model")

    fn = jax.checkpoint(layer) if mcfg.remat else layer
    x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, mcfg.norm_eps)


def decode_train(params, tokens, memory, *, mcfg, mem_mask=None):
    """Teacher-forced decoder.  tokens: (B, S_dec); memory: (B, S_enc, d)."""
    cdt = mcfg.cdtype()
    x = embed(params["tok_embed"], tokens, dtype=cdt)
    B, N, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None], (B, N))
    x = constrain(x, "batch", "seq_res", "d_model")

    def layer(lp, x):
        h = rmsnorm(lp["norm1"], x, mcfg.norm_eps)
        h = attention_layer_apply(lp["self_attn"], h, mcfg=mcfg, causal=True,
                                  positions=positions)
        x = x + h
        h = rmsnorm(lp["norm_x"], x, mcfg.norm_eps)
        mkv = memory_kv(lp["cross_attn"], memory, mcfg=mcfg)
        x = x + cross_attention_apply(lp["cross_attn"], h, mkv, mcfg=mcfg,
                                      mem_mask=mem_mask)
        h = rmsnorm(lp["norm2"], x, mcfg.norm_eps)
        return constrain(x + swiglu(lp["ffn"], h), "batch", "seq", "d_model")

    fn = jax.checkpoint(layer) if mcfg.remat else layer
    x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["dec_layers"])
    x = rmsnorm(params["dec_norm"], x, mcfg.norm_eps)
    return dense(params["lm_head"], x).astype(jnp.float32)


def encdec_loss(params, batch, *, mcfg):
    """batch: {frames, dec_tokens, labels, [frame_mask, loss_mask]}."""
    memory = encode(params, batch["frames"], mcfg=mcfg,
                    mask=batch.get("frame_mask"))
    logits = decode_train(params, batch["dec_tokens"], memory, mcfg=mcfg,
                          mem_mask=batch.get("frame_mask"))
    loss = masked_mean_nll(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def encdec_cache_init(params, memory, *, mcfg, batch, max_len, dtype=jnp.bfloat16):
    """Per-layer: self-attn cache + precomputed cross-attn memory K/V."""
    n_dec = mcfg.n_layers
    def one(i):
        lp = jax.tree.map(lambda t: t[i], params["dec_layers"])
        mk, mv = memory_kv(lp["cross_attn"], memory, mcfg=mcfg)
        return {"self": attention_cache_init(mcfg, batch, max_len, dtype),
                "mem_k": mk.astype(dtype), "mem_v": mv.astype(dtype)}
    caches = [one(i) for i in range(n_dec)]
    return jax.tree.map(lambda *ts: jnp.stack(ts), *caches)


def encdec_decode_step(params, token, caches, *, mcfg, mem_mask=None):
    """token: (B,) → (logits (B,V), caches)."""
    cdt = mcfg.cdtype()
    x1 = embed(params["tok_embed"], token[:, None], dtype=cdt)

    def body(x1, inp):
        lp, pc = inp
        h = rmsnorm(lp["norm1"], x1, mcfg.norm_eps)
        h, new_self = attention_layer_decode(lp["self_attn"], h, pc["self"],
                                             mcfg=mcfg)
        x1 = x1 + h
        h = rmsnorm(lp["norm_x"], x1, mcfg.norm_eps)
        x1 = x1 + cross_attention_apply(
            lp["cross_attn"], h, (pc["mem_k"].astype(cdt), pc["mem_v"].astype(cdt)),
            mcfg=mcfg, mem_mask=mem_mask)
        h = rmsnorm(lp["norm2"], x1, mcfg.norm_eps)
        x1 = x1 + swiglu(lp["ffn"], h)
        return x1, dict(pc, self=new_self)

    x1, new_caches = jax.lax.scan(body, x1, (params["dec_layers"], caches))
    x1 = rmsnorm(params["dec_norm"], x1, mcfg.norm_eps)
    logits = dense(params["lm_head"], x1)
    return logits[:, 0].astype(jnp.float32), new_caches
