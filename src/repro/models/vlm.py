"""VLM (llava-next family): stubbed vision frontend + LM backbone.

Per the assignment spec the modality frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings (anyres tiling happens upstream).  The
projector (2-layer MLP, llava-style) and the backbone are real.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.losses import masked_mean_nll
from repro.layers.nn import dense, dense_init, embed
from repro.models.transformer import lm_apply, lm_init


def vlm_init(key, mcfg) -> dict:
    pd = mcfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    d_v = mcfg.d_frontend or mcfg.d_model
    return {
        "projector": {
            "fc1": dense_init(k1, d_v, mcfg.d_model, param_dtype=pd, bias=True),
            "fc2": dense_init(k2, mcfg.d_model, mcfg.d_model, param_dtype=pd,
                              bias=True),
        },
        "lm": lm_init(k3, mcfg),
    }


def _project(p, patches, cdt):
    h = dense(p["fc1"], patches.astype(cdt))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cdt)
    return dense(p["fc2"], h)


def vlm_apply(params, tokens, patch_embeds, *, mcfg):
    """tokens: (B, S_text); patch_embeds: (B, S_img, d_frontend).
    Sequence = [image tokens; text tokens], total = S_img + S_text."""
    cdt = mcfg.cdtype()
    img = _project(params["projector"], patch_embeds, cdt)
    txt = embed(params["lm"]["embed"], tokens, dtype=cdt)
    x = jnp.concatenate([img, txt], axis=1)
    return lm_apply(params["lm"], mcfg=mcfg, inputs_embeds=x)


def vlm_loss(params, batch, *, mcfg):
    """batch: {tokens (B,S_text), patch_embeds (B,S_img,dv), labels (B,S_text)}."""
    logits, aux_loss = vlm_apply(params, batch["tokens"], batch["patch_embeds"],
                                 mcfg=mcfg)
    S_img = batch["patch_embeds"].shape[1]
    loss = masked_mean_nll(logits[:, S_img:], batch["labels"])
    return loss + 0.01 * aux_loss, {"loss": loss}
