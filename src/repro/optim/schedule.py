"""LR schedules (paper: cosine with lr=1e-3)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, total_steps: int,
                    warmup_steps: int = 0, min_lr_ratio: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.where(warmup_steps > 0, step / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    lr = base_lr * (min_lr_ratio + (1 - min_lr_ratio) * cos)
    return jnp.where(step < warmup_steps, base_lr * warm, lr)
