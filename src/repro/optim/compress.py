"""Gradient compression for the slow cross-pod (DCI) reduction.

int8 block-quantized all-gather-sum with ERROR FEEDBACK: instead of a bf16
ring all-reduce over the ``pod`` axis (2× bytes on the wire), each pod
quantizes its gradient shard to int8 (per-block scale), all-gathers the
int8 payload (¼ the bytes of bf16, and 1× instead of 2×), and sums locally.
The quantization residual is carried in the optimizer state and added to the
next step's gradient — standard EF-SGD, keeps convergence unbiased in the
long run.  Net wire traffic: 8× less than bf16 all-reduce.

Exposed as a ``shard_map``-based transform of per-pod gradients; unit-tested
against exact psum (quantization error bound + error-feedback convergence).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g: jnp.ndarray):
    """per-block int8 quantization; returns (q, scale, residual)."""
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(flat.shape)[:g.size].reshape(g.shape)
    return q, scale.astype(jnp.float32), g - deq


def _dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Inside shard_map: error-feedback int8 'psum' over ``axis_name``.

    Returns (summed gradient ≈ psum(g), new residual)."""
    g = g + err                                  # error feedback
    q, scale, residual = _quantize(g)
    q_all = jax.lax.all_gather(q, axis_name)          # int8 on the wire
    s_all = jax.lax.all_gather(scale, axis_name)
    n = q_all.shape[0]
    total = jnp.zeros(g.shape, jnp.float32)
    for i in range(n):                                # static unroll (n = pods)
        total = total + _dequantize(q_all[i], s_all[i], g.shape)
    return total.astype(g.dtype), residual


def make_compressed_grad_fn(loss_fn, mesh, *, axis_name: str = "pod"):
    """Wrap a loss into a shard_map'd per-pod grad + compressed cross-pod
    reduction.  Gradients w.r.t. REPLICATED params; batch sharded over pod."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def per_pod(params, batch, err):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        out = jax.tree.map(
            lambda g, e: compressed_psum(g, e, axis_name), grads, err)
        grads = jax.tree.map(lambda t: t[0] / mesh.shape[axis_name], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        loss = jax.lax.pmean(loss, axis_name)
        return loss, grads, new_err

    return shard_map(
        per_pod, mesh=mesh,
        in_specs=(P(), P(axis_name), P()),
        out_specs=(P(), P(), P()),
        check_rep=False)
