"""AdamW (decoupled weight decay, paper Appendix A) — pure-pytree functional
optimizer.  Optimizer-state dtype is configurable: fp32 default; bf16 for the
398B config so m/v fit HBM (recorded per-config; see DESIGN §7)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, *, state_dtype=jnp.float32) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.01):
    """Returns (new_params, new_opt_state).  ``lr`` may be a traced scalar."""
    step = opt_state["step"] + 1
    sdt = jax.tree.leaves(opt_state["m"])[0].dtype
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
