"""Model / shape configuration schema and registry.

``ModelConfig`` describes every architecture in the assigned pool plus the
paper's own point-cloud model.  ``SHAPES`` are the four assigned input-shape
cells; ``input_specs`` builds ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

import jax.numpy as jnp

from repro.core.config import BSAConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | vlm | hybrid | audio | pointcloud
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // n_heads

    # --- attention ---
    attention: str = "bsa"           # MECHANISM: bsa | full | erwin.  The
                                     # execution BACKEND (jnp/pallas/interpret/
                                     # plug-in) is orthogonal: bsa.backend —
                                     # see repro.core.backend
    bsa: BSAConfig = dataclasses.field(default_factory=BSAConfig)
    rope_theta: float = 1e4

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    n_shared_experts: int = 0        # Qwen-style fused shared expert (dim = n·moe_d_ff)
    moe_period: int = 1              # MoE FFN every `moe_period` layers
    capacity_factor: float = 1.25
    # EP alignment: pad the expert STACK to this count with inert experts the
    # router can never select (router stays n_experts wide).  E.g. qwen's 60
    # experts pad to 64 so the 16-way model axis shards them 4-per-device —
    # without this the dispatch buffer and expert weights replicate.
    pad_experts_to: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_period: int = 0             # hybrid: one attention layer per this many (0 ⇒ pure)

    # --- encoder-decoder / multimodal ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    dec_ratio: int = 8               # enc-dec: decoder len = seq_len // dec_ratio
    vision_tokens: int = 0           # VLM: patch-embedding stub length
    d_frontend: int = 0              # stubbed modality embedding dim

    # --- numerics / misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True               # activation checkpointing per layer-period

    # --- sharding ---
    attn_shard_mode: str = "head"    # head | sequence (for head counts ∤ TP)
    fsdp: bool = False               # ALSO shard params over DP (ZeRO-3) —
                                     # required when params/TP > HBM (jamba 398B)
    opt_state_dtype: str = "float32" # bf16 for the 398B config (fits HBM; see DESIGN)

    # --- point cloud (paper model) ---
    in_dim: int = 0                  # per-point input features
    out_dim: int = 0                 # regression targets per point

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS = [
    "granite-20b", "tinyllama-1.1b", "phi4-mini-3.8b", "stablelm-1.6b",
    "qwen2-moe-a2.7b", "phi3.5-moe-42b-a6.6b", "mamba2-1.3b",
    "llava-next-34b", "jamba-1.5-large-398b", "seamless-m4t-medium",
]


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        try:
            importlib.import_module(f"repro.configs.{mod}")
        except ModuleNotFoundError:
            # paper-model variants all live in shapenet_bsa.py
            importlib.import_module("repro.configs.shapenet_bsa")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    return list(ARCH_IDS)
