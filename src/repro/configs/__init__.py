"""Architecture configs: the 10 assigned archs + the paper's own models.

BSA hyperparameters: point-cloud configs use the paper's Appendix-A values
verbatim (ball 256, ℓ=8, top-k 4, group 8).  LM configs scale the block
sizes with sequence length exactly as NSA does for long-context text
(ℓ=64, top-k 16, local window 256) — the paper's ℓ=8 was tuned for N≈4k
point sets; at 32k–500k tokens the compression branch (cost N²/ℓ) needs a
larger ℓ.  See DESIGN.md §5.
"""
from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    get_config,
    list_archs,
    register,
)
