"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("stablelm-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=5632, vocab_size=100352,
        attention="bsa", bsa=LM_BSA)
