"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].  Optimizer states kept in bf16 so the 398B config
fits 16 GB/chip HBM on a single pod (DESIGN §7 / EXPERIMENTS §Dry-run)."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=24576, vocab_size=65536,
        moe=True, n_experts=16, experts_per_token=2, moe_d_ff=24576,
        moe_period=2, attn_period=8,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        attention="bsa", bsa=LM_BSA, opt_state_dtype="bfloat16", fsdp=True)
