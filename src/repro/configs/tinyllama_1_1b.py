"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385; hf]."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("tinyllama-1.1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, head_dim=64, d_ff=5632, vocab_size=32000,
        attention="bsa", bsa=LM_BSA)
