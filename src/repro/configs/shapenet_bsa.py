"""The paper's own model: 18-block BSA point-cloud regressor (ShapeNet-Car).

Attention hyperparameters are Appendix-A-exact (ball 256, ℓ=8, top-k 4,
group 8).  The paper does not state d_model/heads; we use d_model=256,
8 heads, SwiGLU d_ff=1024 (Erwin-scale, noted in DESIGN.md).  ShapeNet-Car
has 3586 points → padded to 3840 = 15 balls of 256.  Variants reproduce
Table 3 rows: bsa | bsa_no_group | bsa_group_cmp | full | erwin."""
import dataclasses

from repro.configs.base import ModelConfig, register
from repro.configs.presets import PAPER_BSA


def _base(**kw) -> ModelConfig:
    d = dict(
        name="shapenet-bsa", family="pointcloud", n_layers=18, d_model=256,
        n_heads=8, n_kv_heads=8, head_dim=32, d_ff=1024, vocab_size=0,
        in_dim=7, out_dim=1, attention="bsa", bsa=PAPER_BSA,
        param_dtype="float32", compute_dtype="float32", remat=False)
    d.update(kw)
    return ModelConfig(**d)


@register("shapenet-bsa")
def config() -> ModelConfig:
    return _base()


@register("shapenet-bsa-no-group")
def config_no_group() -> ModelConfig:
    bsa = dataclasses.replace(PAPER_BSA, group_size=0, query_cmp_selection=False)
    return _base(name="shapenet-bsa-no-group", bsa=bsa)


@register("shapenet-bsa-group-cmp")
def config_group_cmp() -> ModelConfig:
    bsa = dataclasses.replace(PAPER_BSA, group_compression=True, phi="mlp")
    return _base(name="shapenet-bsa-group-cmp", bsa=bsa)


@register("shapenet-full")
def config_full() -> ModelConfig:
    return _base(name="shapenet-full", attention="full")


@register("shapenet-erwin")
def config_erwin() -> ModelConfig:
    return _base(name="shapenet-erwin", attention="erwin")


@register("elasticity-bsa")
def config_elasticity() -> ModelConfig:
    # Elasticity benchmark: 972 points → padded to 1024 = 4 balls of 256
    return _base(name="elasticity-bsa", in_dim=6)


@register("elasticity-full")
def config_elasticity_full() -> ModelConfig:
    return _base(name="elasticity-full", in_dim=6, attention="full")
