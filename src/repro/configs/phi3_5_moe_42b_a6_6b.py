"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("phi3.5-moe-42b-a6.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=6400, vocab_size=32064,
        moe=True, n_experts=16, experts_per_token=2, moe_d_ff=6400,
        moe_period=1, attention="bsa", bsa=LM_BSA)
