"""granite-20b [dense] — llama-arch, code [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("granite-20b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense", n_layers=52, d_model=6144,
        n_heads=48, n_kv_heads=1, head_dim=128, d_ff=24576, vocab_size=49152,
        attention="bsa", bsa=LM_BSA)
