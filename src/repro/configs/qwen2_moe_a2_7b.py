"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=151936,
        moe=True, n_experts=60, experts_per_token=4, moe_d_ff=1408,
        n_shared_experts=4, moe_period=1, pad_experts_to=64,
        attention="bsa", bsa=LM_BSA)
