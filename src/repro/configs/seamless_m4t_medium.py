"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

Audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings.  Encoder self-attention uses NON-CAUSAL BSA —
the paper's true point-set form on 1-D frames; decoder uses causal BSA."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("seamless-m4t-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=256206,
        is_encoder_decoder=True, n_encoder_layers=12, d_frontend=1024,
        dec_ratio=8, attention="bsa", bsa=LM_BSA)
