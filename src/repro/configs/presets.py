"""Shared BSA presets (see package docstring for the LM scaling rationale)."""
from repro.core.config import BSAConfig

# paper Appendix A, Table 4 — point-set form
PAPER_BSA = BSAConfig(ball_size=256, cmp_block=8, slc_block=8, top_k=4,
                      group_size=8, query_cmp_selection=True, phi="mean")

# causal-LM form: NSA-scale blocks for long sequences.  jnp_chunk_tokens
# bounds the jnp-fallback's temp memory (the Pallas kernels stream through
# VMEM on real TPUs and ignore it).
LM_BSA = BSAConfig(ball_size=256, local_window=256, cmp_block=64, slc_block=64,
                   top_k=16, group_size=64, query_cmp_selection=True, phi="mean",
                   jnp_chunk_tokens=256)
