"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

BSA is attention-specific and does NOT apply to this attention-free arch
(DESIGN.md §Arch-applicability); the arch is implemented faithfully with the
chunked SSD algorithm, which is itself sub-quadratic (long_500k runs)."""
from repro.configs.base import ModelConfig, register


@register("mamba2-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, attention="none")
