"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6; unverified].

Vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (d=1024, CLIP-L-scale); projector + backbone
are real.  56 heads are not divisible by the 16-way model axis → attention
shards by SEQUENCE (balls are independent ⇒ BSA allows TP-axis sequence
sharding; DESIGN §4) while the FFN stays tensor-parallel."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("llava-next-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
        vision_tokens=512, d_frontend=1024,
        attention="bsa", bsa=LM_BSA, attn_shard_mode="sequence")
