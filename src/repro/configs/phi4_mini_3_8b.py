"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from repro.configs.base import ModelConfig, register
from repro.configs.presets import LM_BSA


@register("phi4-mini-3.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=200064,
        attention="bsa", bsa=LM_BSA)
