"""Reduced ("smoke") configs: same family structure, tiny dimensions.

Used by per-arch CPU smoke tests and the small-mesh dry-run test.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.config import BSAConfig

SMOKE_SEQ = 256

SMOKE_BSA = BSAConfig(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
                      top_k=2, group_size=8, query_cmp_selection=True)


def smoke_config(mcfg: ModelConfig) -> ModelConfig:
    """Scale an arch config down to CPU-smoke size, preserving structure."""
    # layers: one period of the layer pattern (two for trivial patterns)
    if mcfg.attn_period:
        n_layers = mcfg.attn_period
    elif mcfg.moe and mcfg.moe_period > 1:
        n_layers = 2 * mcfg.moe_period
    else:
        n_layers = 2

    if mcfg.n_heads:
        rep = max(1, min(mcfg.n_heads // max(mcfg.n_kv_heads, 1), 4))
        n_heads = 4
        n_kv_heads = max(1, 4 // rep)
    else:
        n_heads = n_kv_heads = 0

    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=16 if n_heads else 0,
        d_ff=128 if mcfg.d_ff else 0,
        vocab_size=512 if mcfg.vocab_size else 0,
        bsa=SMOKE_BSA,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
    if mcfg.moe:
        kw.update(n_experts=min(8, mcfg.n_experts),
                  experts_per_token=min(2, mcfg.experts_per_token),
                  moe_d_ff=32,
                  n_shared_experts=min(1, mcfg.n_shared_experts),
                  capacity_factor=2.0)
    if mcfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_expand=2)
    if mcfg.family == "vlm":
        kw.update(vision_tokens=32, d_frontend=32)
    if mcfg.family == "audio":
        kw.update(n_encoder_layers=2, d_frontend=32, dec_ratio=4)
    if mcfg.family == "pointcloud":
        kw.update(in_dim=mcfg.in_dim, out_dim=mcfg.out_dim, n_layers=2)
    return dataclasses.replace(mcfg, **kw)
