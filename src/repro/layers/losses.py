"""Vocab-parallel cross-entropy (gather-free).

``take_along_axis`` on vocab-sharded logits forces GSPMD to all-gather the
full (B, N, V) tensor per device (8+ GiB at 32k vocab); the one-hot-masked
sum keeps every operand sharded over vocab and lowers to one small
all-reduce.  Backward (softmax − onehot) stays sharded too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def vocab_parallel_nll(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits: (..., V) fp32 (may be vocab-sharded); labels: (...) int32.
    Returns per-position negative log-likelihood (...)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, labels.shape + (vocab,), labels.ndim)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return lse - gold


def masked_mean_nll(logits, labels, loss_mask=None):
    nll = vocab_parallel_nll(logits, labels)
    if loss_mask is not None:
        nll = jnp.where(loss_mask, nll, 0.0)
        denom = jnp.maximum(loss_mask.sum(), 1)
    else:
        denom = nll.size
    return nll.sum() / denom
