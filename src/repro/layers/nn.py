"""Minimal functional NN layer library (pure JAX pytrees, no framework).

Every layer is a pair of functions:
  ``<layer>_init(key, ...) -> params``   (params: nested dict of jnp arrays)
  ``<layer>(params, x, ...) -> y``

Parameters are created in ``param_dtype`` and compute runs in the dtype of
the inputs (matmuls accumulate in fp32 via ``preferred_element_type``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Params = dict


def _he_scale(fan_in: int) -> float:
    return (2.0 / max(fan_in, 1)) ** 0.5


def dense_init(key, d_in: int, d_out: int, *, param_dtype=jnp.float32,
               scale: float | None = None, bias: bool = False) -> Params:
    if scale is None:
        scale = _he_scale(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(param_dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), param_dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...i,io->...o", x, p["w"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_init(d: int, *, param_dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), param_dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * p["g"].astype(x.dtype)


def swiglu_init(key, d_model: int, d_ff: int, *, param_dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, param_dtype=param_dtype),
        "up": dense_init(k2, d_model, d_ff, param_dtype=param_dtype),
        "down": dense_init(k3, d_ff, d_model, param_dtype=param_dtype,
                           scale=_he_scale(d_ff)),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = dense(p["gate"], x)
    u = dense(p["up"], x)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return dense(p["down"], h)


def mlp_init(key, d_in: int, d_hidden: int, d_out: int, *, param_dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "fc1": dense_init(k1, d_in, d_hidden, param_dtype=param_dtype, bias=True),
        "fc2": dense_init(k2, d_hidden, d_out, param_dtype=param_dtype,
                          scale=_he_scale(d_hidden), bias=True),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(dense(p["fc1"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["fc2"], h)


def embed_init(key, vocab: int, d_model: int, *, param_dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * (1.0 / d_model ** 0.5)).astype(param_dtype)}


def embed(p: Params, ids: jnp.ndarray, dtype=None) -> jnp.ndarray:
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, ids, axis=0)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied (or untied) logits projection: x (..., d) @ table.T -> (..., vocab)."""
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype),
                      preferred_element_type=jnp.float32)
