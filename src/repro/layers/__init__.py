from repro.layers.nn import (  # noqa: F401
    dense_init,
    dense,
    rmsnorm_init,
    rmsnorm,
    swiglu_init,
    swiglu,
    embed_init,
    embed,
    mlp_init,
    mlp,
)
from repro.layers.rope import rope_freqs, apply_rope  # noqa: F401
