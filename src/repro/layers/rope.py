"""Rotary position embeddings (RoPE)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Apply RoPE.  x: (..., seq, heads, head_dim); positions: broadcastable to
    (..., seq) int32.  Rotation computed in fp32, returned in x.dtype."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
