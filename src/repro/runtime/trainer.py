"""Production training loop: jit'd train_step with sharded state, periodic
async checkpoints, preemption-safe save (SIGTERM), straggler watchdog,
resume / elastic restart.

The same Trainer drives the paper's point-cloud training and the LM archs
(everything routes through ``models.api.model_api``).
"""

from __future__ import annotations

import dataclasses
import signal
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step
from repro.distributed.params import batch_shardings, opt_shardings, param_shardings
from repro.distributed.sharding import axis_rules
from repro.launch.steps import make_train_step
from repro.optim import adamw_init
from repro.runtime.watchdog import Watchdog


@dataclasses.dataclass
class TrainerConfig:
    base_lr: float = 1e-3
    weight_decay: float = 0.01
    total_steps: int = 100_000
    warmup_steps: int = 1000
    max_grad_norm: float = 1.0
    ckpt_dir: str | None = None
    ckpt_every: int = 500
    keep_last: int = 3
    log_every: int = 50
    seed: int = 0


class Trainer:
    def __init__(self, api, cfg: TrainerConfig, *, mesh=None, rules=None):
        self.api = api
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules or {}
        self.watchdog = Watchdog().start()
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep_last=cfg.keep_last)
                     if cfg.ckpt_dir else None)
        self._preempted = False
        self.metrics_history: list[dict] = []

        step_fn = make_train_step(
            api, base_lr=cfg.base_lr, weight_decay=cfg.weight_decay,
            total_steps=cfg.total_steps, warmup_steps=cfg.warmup_steps,
            max_grad_norm=cfg.max_grad_norm)

        if mesh is not None:
            pstruct = jax.eval_shape(api.init, jax.random.PRNGKey(cfg.seed))
            ostruct = jax.eval_shape(
                lambda p: adamw_init(p, state_dtype=jnp.dtype(api.mcfg.opt_state_dtype)),
                pstruct)
            self.p_sh = param_shardings(pstruct, mesh, zero1=api.mcfg.fsdp)
            self.o_sh = opt_shardings(ostruct, mesh)
            self._jit_step = jax.jit(step_fn, in_shardings=(self.p_sh, self.o_sh, None),
                                     donate_argnums=(0, 1))
        else:
            self.p_sh = self.o_sh = None
            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------

    def init_state(self):
        with self._ctx():
            params = jax.jit(self.api.init)(jax.random.PRNGKey(self.cfg.seed))
            if self.p_sh is not None:
                params = jax.device_put(params, self.p_sh)
            opt_state = adamw_init(
                params, state_dtype=jnp.dtype(self.api.mcfg.opt_state_dtype))
            if self.o_sh is not None:
                opt_state = jax.device_put(opt_state, self.o_sh)
        return params, opt_state

    def _ctx(self):
        if self.mesh is not None:
            return axis_rules(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def maybe_restore(self, params, opt_state):
        """Resume from the newest checkpoint if one exists (elastic: the
        target shardings may correspond to a different mesh than at save)."""
        if self.ckpt is None or latest_step(self.cfg.ckpt_dir) is None:
            return params, opt_state, 0
        state, meta = self.ckpt.restore(
            {"params": params, "opt": opt_state},
            shardings=({"params": self.p_sh, "opt": self.o_sh}
                       if self.p_sh is not None else None))
        return state["params"], state["opt"], meta["step"]

    def _install_sigterm(self, get_state):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    # ------------------------------------------------------------------

    def fit(self, batches, *, steps: int, params=None, opt_state=None,
            start_step: int | None = None):
        """Run ``steps`` optimizer steps over ``batches`` (iterator of pytrees)."""
        if params is None:
            params, opt_state = self.init_state()
            params, opt_state, restored = self.maybe_restore(params, opt_state)
        else:
            restored = 0
        step0 = restored if start_step is None else start_step
        self._install_sigterm(lambda: (params, opt_state))

        it = iter(batches)
        t_train0 = time.time()
        for step in range(step0, step0 + steps):
            batch = next(it)
            state = batch.pop("_state", None)
            if self.mesh is not None:
                b_sh = batch_shardings(
                    jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                                 batch), self.mesh)
                batch = jax.device_put(batch, b_sh)
            t0 = time.time()
            with self._ctx():
                params, opt_state, metrics = self._jit_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            self.watchdog.step(step, dt)

            if step % self.cfg.log_every == 0 or step == step0 + steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, step_time_s=round(dt, 4))
                self.metrics_history.append(m)
                print(f"step {step:6d}  loss {m['loss']:.4f}  "
                      f"gnorm {m.get('grad_norm', 0):.2f}  {dt*1e3:.0f} ms",
                      flush=True)
            if self.ckpt and (step % self.cfg.ckpt_every == 0 or self._preempted
                              or step == step0 + steps - 1) and step > step0:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               extra={"data_state": state} if state else None,
                               block=self._preempted)
                if self._preempted:
                    print(f"preempted: state saved at step {step}", flush=True)
                    break
        self.watchdog.stop()
        if self.ckpt:
            self.ckpt.wait()
        self.wall_time = time.time() - t_train0
        return params, opt_state
