"""Straggler / hang detection for the training loop.

Per-step wall time is tracked with an EWMA; a step slower than
``straggler_factor`` × EWMA fires the straggler callback (on a real cluster:
report the slow host to the coordinator, trigger redistribution or hot-spare
swap; here: logged + counted, unit-tested).  A watchdog thread fires the
hang callback if no heartbeat arrives within ``hang_timeout`` seconds —
preemption-style recovery (checkpoint is already on disk; the job restarts
elastically via checkpoint.restore on the surviving mesh).
"""

from __future__ import annotations

import threading
import time


class Watchdog:
    def __init__(self, *, straggler_factor: float = 3.0, hang_timeout: float = 300.0,
                 on_straggler=None, on_hang=None, ewma: float = 0.9):
        self.straggler_factor = straggler_factor
        self.hang_timeout = hang_timeout
        self.on_straggler = on_straggler
        self.on_hang = on_hang
        self.ewma_coef = ewma
        self.ewma: float | None = None
        self.straggler_events: list[tuple[int, float, float]] = []
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- heartbeat thread --

    def start(self):
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)

    def _watch(self):
        while not self._stop.wait(min(self.hang_timeout / 4, 5.0)):
            if time.monotonic() - self._last_beat > self.hang_timeout:
                if self.on_hang:
                    self.on_hang()
                self._last_beat = time.monotonic()

    # -- per-step --

    def step(self, step_idx: int, duration: float):
        self._last_beat = time.monotonic()
        if self.ewma is None:
            self.ewma = duration
            return False
        is_straggler = duration > self.straggler_factor * self.ewma
        if is_straggler:
            self.straggler_events.append((step_idx, duration, self.ewma))
            if self.on_straggler:
                self.on_straggler(step_idx, duration, self.ewma)
        # slow steps should not poison the baseline
        coef = self.ewma_coef if not is_straggler else 0.995
        self.ewma = coef * self.ewma + (1 - coef) * duration
        return is_straggler
