"""Serving engines: LM decode slots and batched ragged geometry inference.

``ServingEngine`` — static-batched generation: a fixed number of slots
decode in lockstep (the BSA decode cache tracks one shared position — DESIGN
§4 notes per-slot lengths as the continuous-batching extension).  Prefill is
DECODE REPLAY: prompts stream token-by-token through ``serve_step``, which
is exactly the cache semantics the train path matches (unit-tested
bit-consistency), so generation after a replayed prefill equals teacher
forcing.  Jit boundaries: one compiled ``serve_step`` reused for prefill
and decode.

``GeometryEngine`` — the batched path for variable-size point clouds: each
request cloud is ball-tree ordered on the host, packed with its batch-mates
into one padded (B, L, ·) batch + per-sample mask
(``core.balltree.pack_ragged``), pushed through ONE jitted forward, and
un-packed / inverse-permuted back to per-cloud predictions.  Padded lengths
are quantised to geometric buckets so the number of distinct compiled shapes
stays logarithmic in the size range.
"""

from __future__ import annotations

import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import use_backend
from repro.core.balltree import (bucket_length, pack_ragged, pack_varlen,
                                 build_balltree_permutations, unpack_ragged,
                                 unpack_varlen)
from repro.launch.steps import make_serve_step


def _backend_scope(name: str | None):
    """Fresh context forcing attention backend ``name`` (None = config's).

    Backend resolution is TRACE-time, so wrapping every jitted call is
    enough: the first call bakes the backend into the compiled step and
    later calls replay it."""
    return use_backend(name) if name else contextlib.nullcontext()


class ServingEngine:
    def __init__(self, api, params, *, batch_slots: int, max_len: int,
                 cache_dtype=jnp.float32, temperature: float = 0.0, seed: int = 0,
                 backend: str | None = None):
        self.api = api
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.backend = backend          # attention-backend override (by name)
        self._rng = jax.random.PRNGKey(seed)
        self.caches = api.cache_init(batch_slots, max_len, cache_dtype)
        self._step = jax.jit(make_serve_step(api))
        self.tokens_generated = 0
        self.decode_time = 0.0

    def reset(self, cache_dtype=jnp.float32):
        self.caches = self.api.cache_init(self.B, self.max_len, cache_dtype)

    def prefill(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, P) int32 — replayed through the decode path.
        Returns last logits' argmax (first generated token)."""
        assert prompts.shape[0] == self.B
        nxt = None
        with _backend_scope(self.backend):
            for t in range(prompts.shape[1]):
                tok = jnp.asarray(prompts[:, t], jnp.int32)
                nxt, logits, self.caches = self._step(self.params, self.caches, tok)
        return np.asarray(nxt)

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_tokens: int) -> np.ndarray:
        """Greedy/temperature generation.  Returns (B, n_tokens)."""
        first = self.prefill(prompts)
        out = [first]
        tok = jnp.asarray(first)
        t0 = time.time()
        with _backend_scope(self.backend):
            for _ in range(n_tokens - 1):
                nxt, logits, self.caches = self._step(self.params, self.caches, tok)
                tok = self._sample(logits)
                out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        self.decode_time += time.time() - t0
        self.tokens_generated += self.B * n_tokens
        return np.stack(out, axis=1)

    @property
    def tokens_per_second(self) -> float:
        return self.tokens_generated / max(self.decode_time, 1e-9)


class GeometryEngine:
    """Batched inference over ragged point clouds (the pointcloud family).

    Requests are (points, feats) pairs of ANY sizes; the engine owns the
    whole ragged pipeline: per-cloud ball-tree permutation → pack to a
    bucketed length with per-sample masks → one jitted batched forward →
    unpack + inverse-permute.  Clouds are served in request order, grouped
    into batches of ``batch_slots``.

    Two batch LAYOUTS (docs/varlen.md):

    * ``"packed"`` (default when the model runs BSA) — clouds concatenated
      on ONE packed axis with an ``offsets`` boundary array
      (``core.balltree.pack_varlen``); no dummy batch slots, no
      per-slot padding to the largest cloud, so the forward spends FLOPs
      proportional to Σnᵢ rather than B·max(nᵢ).
    * ``"padded"`` — the classic (B, L, ·) bucket-padded batch with
      per-sample masks; required for non-BSA attention mechanisms, whose
      layers don't take offsets.

    ``pad_to`` freezes the compiled length (use the dataset's
    ``max_padded_len`` when the size range is known): the per-slot padded
    length in ``"padded"`` layout, the TOTAL packed capacity in
    ``"packed"``.  Otherwise each batch pads to a geometric bucket (of the
    largest cloud, resp. of the packed total), giving at most
    O(log size-range) compilations.  A short final batch costs nothing
    extra when packed (offsets simply repeat); padded layout fills it with
    fully-masked dummy slots rather than recompiling at a smaller B.
    """

    def __init__(self, api, params, *, batch_slots: int = 8,
                 pad_to: int | None = None, backend: str | None = None,
                 layout: str | None = None):
        self.api = api
        self.params = params
        self.batch_slots = batch_slots
        self.pad_to = pad_to
        self.backend = backend          # attention-backend override (by name)
        if layout is None:
            layout = "packed" if api.mcfg.attention == "bsa" else "padded"
        if layout not in ("packed", "padded"):
            raise ValueError(f"layout must be 'packed' or 'padded', got {layout!r}")
        self.layout = layout
        self.ball_size = api.mcfg.bsa.ball_size
        self._fwd = jax.jit(api.forward)
        self.clouds_served = 0
        self.points_served = 0
        self.predict_time = 0.0

    def predict(self, clouds) -> list[np.ndarray]:
        """clouds: sequence of ``(points (n_i, d), feats (n_i, in_dim))``
        pairs (or dicts with those keys).  Returns one (n_i, out_dim) array
        per cloud, rows in the CALLER's original point order."""
        clouds = [(c["points"], c["feats"]) if isinstance(c, dict) else c
                  for c in clouds]
        results: list[np.ndarray] = []
        t0 = time.time()
        for s in range(0, len(clouds), self.batch_slots):
            results.extend(self._predict_batch(clouds[s:s + self.batch_slots]))
        self.predict_time += time.time() - t0
        self.clouds_served += len(clouds)
        self.points_served += sum(int(np.asarray(p).shape[0]) for p, _ in clouds)
        return results

    def _predict_batch(self, chunk) -> list[np.ndarray]:
        pts_list = [np.asarray(p) for p, _ in chunk]
        fts_list = [np.asarray(f, np.float32) for _, f in chunk]
        perms = build_balltree_permutations(pts_list, self.ball_size)
        ordered = [f[perm] for f, perm in zip(fts_list, perms)]
        if self.layout == "packed":
            feats, offsets, mask = pack_varlen(
                ordered, self.ball_size, pad_to=self.pad_to,
                max_samples=self.batch_slots)
            with _backend_scope(self.backend):
                pred = self._fwd(self.params,
                                 {"feats": jnp.asarray(feats)[None],
                                  "mask": jnp.asarray(mask)[None],
                                  "offsets": jnp.asarray(offsets)})
            per_cloud = unpack_varlen(np.asarray(pred)[0],
                                      offsets[:len(chunk) + 1], mask)
            out = []
            for rows, perm in zip(per_cloud, perms):
                unperm = np.empty_like(rows)
                unperm[perm] = rows                # ball order → original order
                out.append(unperm)
            return out
        target = self.pad_to or bucket_length(
            max(f.shape[0] for f in ordered), self.ball_size)
        # fully-masked dummy slots keep B static for the final short batch
        # (every branch returns exact zeros for an all-invalid sample)
        pad_slots = self.batch_slots - len(chunk)
        if pad_slots > 0:
            ordered += [np.zeros((1, ordered[0].shape[1]), np.float32)] * pad_slots
        feats, mask = pack_ragged(ordered, self.ball_size, pad_to=target)
        if pad_slots > 0:
            mask[len(chunk):] = False
        with _backend_scope(self.backend):
            pred = self._fwd(self.params, {"feats": jnp.asarray(feats),
                                           "mask": jnp.asarray(mask)})
        per_cloud = unpack_ragged(np.asarray(pred), mask)[:len(chunk)]
        out = []
        for rows, perm in zip(per_cloud, perms):
            unperm = np.empty_like(rows)
            unperm[perm] = rows                    # ball order → original order
            out.append(unperm)
        return out

    @property
    def points_per_second(self) -> float:
        return self.points_served / max(self.predict_time, 1e-9)
