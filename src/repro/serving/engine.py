"""Batched serving engine over the decode path.

Static-batched generation: a fixed number of slots decode in lockstep (the
BSA decode cache tracks one shared position — DESIGN §4 notes per-slot
lengths as the continuous-batching extension).  Prefill is DECODE REPLAY:
prompts stream token-by-token through ``serve_step``, which is exactly the
cache semantics the train path matches (unit-tested bit-consistency), so
generation after a replayed prefill equals teacher forcing.

Jit boundaries: one compiled ``serve_step`` reused for prefill and decode.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_serve_step


class ServingEngine:
    def __init__(self, api, params, *, batch_slots: int, max_len: int,
                 cache_dtype=jnp.float32, temperature: float = 0.0, seed: int = 0):
        self.api = api
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self._rng = jax.random.PRNGKey(seed)
        self.caches = api.cache_init(batch_slots, max_len, cache_dtype)
        self._step = jax.jit(make_serve_step(api))
        self.tokens_generated = 0
        self.decode_time = 0.0

    def reset(self, cache_dtype=jnp.float32):
        self.caches = self.api.cache_init(self.B, self.max_len, cache_dtype)

    def prefill(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, P) int32 — replayed through the decode path.
        Returns last logits' argmax (first generated token)."""
        assert prompts.shape[0] == self.B
        nxt = None
        for t in range(prompts.shape[1]):
            tok = jnp.asarray(prompts[:, t], jnp.int32)
            nxt, logits, self.caches = self._step(self.params, self.caches, tok)
        return np.asarray(nxt)

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_tokens: int) -> np.ndarray:
        """Greedy/temperature generation.  Returns (B, n_tokens)."""
        first = self.prefill(prompts)
        out = [first]
        tok = jnp.asarray(first)
        t0 = time.time()
        for _ in range(n_tokens - 1):
            nxt, logits, self.caches = self._step(self.params, self.caches, tok)
            tok = self._sample(logits)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        self.decode_time += time.time() - t0
        self.tokens_generated += self.B * n_tokens
        return np.stack(out, axis=1)

    @property
    def tokens_per_second(self) -> float:
        return self.tokens_generated / max(self.decode_time, 1e-9)
