"""Serving engines: LM decode slots and batched ragged geometry inference.

``ServingEngine`` — two LM generation modes sharing one projection/decode
numeric core:

* LOCKSTEP (``generate``): a fixed number of slots decode together with one
  shared cache position.  Prefill is DECODE REPLAY: prompts stream
  token-by-token through ``serve_step``, which is exactly the cache
  semantics the train path matches (unit-tested bit-consistency), so
  generation after a replayed prefill equals teacher forcing.
* CONTINUOUS BATCHING (``paged=True``, ``serve``): slots hold independent
  requests at independent positions over a PAGED KV cache
  (``serving/paged_cache.py`` block tables + ``nsa_causal_decode_paged``).
  Every step advances every occupied slot one token — prefill replay and
  decode interleave freely — finished slots retire on EOS and freed slots
  admit queued requests mid-flight; hash-chained prefix caching reuses
  cached KV blocks across requests sharing prompt prefixes (copy-on-write
  on divergence).  docs/serving.md walks the lifecycle.

Jit boundaries: ONE compiled step per mode (the paged step takes the block
table + per-slot lengths as data, so admissions never recompile).

``GeometryEngine`` — the batched path for variable-size point clouds: each
request cloud is ball-tree ordered on the host, packed with its batch-mates
into one padded (B, L, ·) batch + per-sample mask
(``core.balltree.pack_ragged``), pushed through ONE jitted forward, and
un-packed / inverse-permuted back to per-cloud predictions.  Padded lengths
are quantised to geometric buckets so the number of distinct compiled shapes
stays logarithmic in the size range.
"""

from __future__ import annotations

import contextlib
import math
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import use_backend
from repro.core.balltree import (bucket_length, pack_ragged, pack_varlen,
                                 build_balltree_permutations, unpack_ragged,
                                 unpack_varlen)
from repro.launch.steps import (make_paged_serve_step, make_paged_serve_window,
                                make_serve_step)
from repro.serving.paged_cache import PagedKVCache


def _backend_scope(name: str | None, mesh_info=None):
    """Fresh context forcing attention backend ``name`` (None = config's).

    Backend resolution is TRACE-time, so wrapping every jitted call is
    enough: the first call bakes the backend into the compiled step and
    later calls replay it.  ``mesh_info`` — a (mesh, axis) pair captured at
    engine construction — re-enters :func:`mesh_context` around the call so
    mesh-requiring backends resolve their mesh even when the engine is used
    outside the user's original ``with mesh_context(...)`` block."""
    stack = contextlib.ExitStack()
    if name:
        stack.enter_context(use_backend(name))
    if mesh_info is not None:
        from repro.distributed.sharded_backend import mesh_context
        stack.enter_context(mesh_context(mesh_info[0], axis=mesh_info[1]))
    return stack


def _require_mesh_if_needed(backend_name: str | None, api, engine: str):
    """(mesh, axis) when the engine's effective backend needs a mesh.

    Fails fast at construction with an actionable error instead of crashing
    inside ``shard_map`` at first trace.  Resolution mirrors the backend
    precedence (config < engine override < env)."""
    import os
    eff = (os.environ.get("REPRO_ATTENTION_BACKEND") or backend_name
           or getattr(api.mcfg.bsa, "backend", None) or "auto")
    from repro.core.backend import get_backend
    try:
        bk = get_backend(eff)
    except KeyError:
        return None          # unknown names error later, in use_backend
    if not getattr(bk, "requires_mesh", False):
        return None
    from repro.distributed.sharded_backend import current_mesh_axis
    ctx = current_mesh_axis()
    if ctx is None:
        raise ValueError(
            f"{engine}(backend={eff!r}) needs an active mesh: construct the "
            "engine inside a mesh context, e.g.\n"
            "    from repro.distributed import mesh_context\n"
            "    from repro.launch.mesh import make_local_mesh\n"
            "    with mesh_context(make_local_mesh()):\n"
            f"        engine = {engine}(...)\n"
            "(the engine captures the mesh, so later calls may happen "
            "outside the with-block)")
    return ctx


class ServingEngine:
    def __init__(self, api, params, *, batch_slots: int, max_len: int,
                 cache_dtype=jnp.float32, temperature: float = 0.0, seed: int = 0,
                 backend: str | None = None, paged: bool = False,
                 page: int | None = None, num_blocks: int | None = None,
                 prefix_cache: bool = True):
        """``paged=True`` enables the continuous-batching mode (``serve``):
        ``page`` tokens per pool block (default: the smallest size aligned
        to both the local window and the compression block), ``num_blocks``
        pool blocks shared by all slots (default: full dedicated capacity,
        ``batch_slots · max_len/page`` — prefix sharing then only ADDS
        headroom), ``prefix_cache`` toggles cross-request prefix block
        reuse (forced off for models with recurrent per-slot state, which a
        cached KV page cannot restore)."""
        self.api = api
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.backend = backend          # attention-backend override (by name)
        # fail fast (with a recipe) if a mesh-requiring backend was asked
        # for outside mesh_context(); capture the mesh for later calls
        self._mesh = _require_mesh_if_needed(backend, api, "ServingEngine")
        self.cache_dtype = cache_dtype
        self._rng = jax.random.PRNGKey(seed)
        self.paged = paged
        if paged:
            if not api.has_paged_decoder:
                raise ValueError(f"family {api.mcfg.family!r} has no paged "
                                 "decode path")
            if page is None:
                bsa = api.mcfg.bsa
                page = math.lcm(bsa.effective_local_window, bsa.cmp_block)
            if max_len % page:
                raise ValueError(f"max_len={max_len} not a multiple of "
                                 f"page={page}")
            self.page = page
            self.n_pages = max_len // page
            self.num_blocks = num_blocks or batch_slots * self.n_pages
            if self._mesh is not None:
                # sharded decode row-partitions the flat pools: bump the
                # block count until both pool row counts divide the mesh
                # axis (extra blocks only add headroom)
                p = self._mesh[0].shape[self._mesh[1]]
                cpp = page // api.mcfg.bsa.cmp_block
                while ((self.num_blocks + 1) * page) % p or \
                        ((self.num_blocks + 1) * cpp) % p:
                    self.num_blocks += 1
            self._prefix_enabled = prefix_cache and not api.has_recurrent_state
            self._pstep = jax.jit(make_paged_serve_step(api, page=page))
            self._wstep = jax.jit(make_paged_serve_window(api, page=page))
            self._copy = jax.jit(
                lambda c, s, d: api.cache_copy_block(c, s, d, page))
            self._reset_slot = jax.jit(api.cache_reset_slot)
            self._alloc_state()
        else:
            self.caches = api.cache_init(batch_slots, max_len, cache_dtype)
        self._step = jax.jit(make_serve_step(api))
        self.tokens_generated = 0
        self.decode_time = 0.0
        self.serve_steps = 0

    def _alloc_state(self):
        self.kv = PagedKVCache(n_slots=self.B, num_blocks=self.num_blocks,
                               page=self.page, n_pages=self.n_pages,
                               prefix_cache=self._prefix_enabled)
        self.caches = self.api.paged_cache_init(self.B, self.num_blocks,
                                                self.page, self.cache_dtype)

    def reset(self, cache_dtype=None):
        """Drop all cached state.  ``cache_dtype=None`` keeps the dtype the
        engine was constructed with; passing one switches it from here on."""
        if cache_dtype is not None:
            self.cache_dtype = cache_dtype
        if self.paged:
            self._alloc_state()
        else:
            self.caches = self.api.cache_init(self.B, self.max_len,
                                              self.cache_dtype)

    def prefill(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (B, P) int32 — replayed through the decode path.
        Returns last logits' argmax (first generated token)."""
        assert prompts.shape[0] == self.B
        nxt = None
        with _backend_scope(self.backend, self._mesh):
            for t in range(prompts.shape[1]):
                tok = jnp.asarray(prompts[:, t], jnp.int32)
                nxt, logits, self.caches = self._step(self.params, self.caches, tok)
        return np.asarray(nxt)

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 eos_id: int | None = None, pad_id: int = 0) -> np.ndarray:
        """Greedy/temperature generation.  Returns (B, n_tokens).

        With ``eos_id`` set, a slot that samples it RETIRES: its remaining
        columns are ``pad_id``, it stops being sampled (and counted), and
        the loop exits early once every slot is done instead of burning
        decode steps on a fully-retired batch."""
        first = np.asarray(self.prefill(prompts))
        done = np.zeros(self.B, bool)
        if eos_id is not None:
            done |= first == eos_id
        emit = np.where(done, pad_id, first).astype(np.int32)
        out = [emit]
        self.tokens_generated += int((~done).sum())
        tok = jnp.asarray(emit)
        t0 = time.time()
        with _backend_scope(self.backend, self._mesh):
            for _ in range(n_tokens - 1):
                if done.all():
                    break
                nxt, logits, self.caches = self._step(self.params, self.caches,
                                                      tok)
                s = np.asarray(self._sample(logits))
                if eos_id is not None:
                    done |= s == eos_id
                emit = np.where(done, pad_id, s).astype(np.int32)
                out.append(emit)
                self.tokens_generated += int((~done).sum())
                tok = jnp.asarray(emit)
        jax.block_until_ready(tok)
        self.decode_time += time.time() - t0
        while len(out) < n_tokens:                   # early-exit padding
            out.append(np.full(self.B, pad_id, np.int32))
        return np.stack(out, axis=1)

    # -- continuous batching over the paged cache ---------------------------

    def serve(self, prompts, max_new_tokens: int,
              eos_id: int | None = None) -> list[np.ndarray]:
        """Continuous-batching generation over an arbitrary request list.

        ``prompts``: sequence of 1-D int token arrays (ANY lengths up to
        ``max_len``).  Returns one generated-token array per prompt (EOS
        excluded, at most ``max_new_tokens``; a slot also stops at cache
        capacity).  Iteration-level scheduling: every engine step advances
        every occupied slot by one token — replaying its prompt (prefill)
        or feeding its last sample (decode) — so short requests drain early
        and their slots admit queued work mid-flight.
        """
        if not self.paged:
            raise RuntimeError("serve() requires ServingEngine(paged=True)")
        prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        if eos_id is None and self.temperature <= 0.0:
            return self._serve_windowed(prompts, max_new_tokens)
        results: list = [None] * len(prompts)
        queue = deque(range(len(prompts)))
        kv = self.kv
        slot_req = np.full(self.B, -1, np.int64)
        slot_feed = np.zeros(self.B, np.int32)
        slot_decode = np.zeros(self.B, bool)    # feed = last sample, not prompt
        slot_gen: list[list] = [[] for _ in range(self.B)]
        # EOS makes the schedule VALUE-dependent: the sample must come back to
        # the host every step to decide retirement.  Without it the schedule
        # is length-only, so steps pipeline: samples feed back device-side
        # (prev → where(slot_decode)) and the whole token history is pulled
        # ONCE at the end — the same async-dispatch regime lockstep prefill
        # enjoys, now covering decode too.
        sync = eos_id is not None
        hist: list = []                          # (B,) device samples per step
        dev_table, tver = None, -1
        prev = None
        t0 = time.time()
        with _backend_scope(self.backend, self._mesh):
            while queue or (slot_req >= 0).any():
                # 1) admission into free slots (prefix-reuse aware)
                for s in range(self.B):
                    if slot_req[s] < 0 and queue:
                        rid = queue.popleft()
                        reused = kv.admit(s, prompts[rid])
                        if self.api.has_recurrent_state:
                            self.caches = self._reset_slot(self.caches, s)
                        slot_req[s] = rid
                        slot_gen[s] = []
                        slot_feed[s] = prompts[rid][reused]
                        slot_decode[s] = False
                # 2) make every occupied slot's next position writable
                for s in np.nonzero(slot_req >= 0)[0]:
                    for op in kv.prepare_append(int(s)):
                        self.caches = self._copy(self.caches, op.src, op.dst)
                if kv.version != tver:           # table changed since last push
                    dev_table = jnp.asarray(kv.table.copy())
                    tver = kv.version
                # 3) one decode step for the whole batch.  Host arrays are
                # pushed as COPIES: with async dispatch the step may still be
                # in flight when step 4 mutates them, and the CPU backend can
                # alias a pushed numpy buffer instead of copying it.
                tok = jnp.asarray(slot_feed.copy())
                if not sync and prev is not None and slot_decode.any():
                    tok = jnp.where(jnp.asarray(slot_decode.copy()), prev, tok)
                nxt, logits, self.caches = self._pstep(
                    self.params, self.caches, tok, dev_table,
                    jnp.asarray(kv.lengths.copy()))
                prev = nxt if self.temperature <= 0.0 else self._sample(logits)
                if sync:
                    sampled = np.asarray(prev)
                else:
                    hist.append(prev)
                self.serve_steps += 1
                # 4) commit, transition, retire, publish prefix pages
                step_idx = len(hist) - 1
                for s in range(self.B):
                    rid = int(slot_req[s])
                    if rid < 0:
                        continue
                    prompt = prompts[rid]
                    fed_pos = int(kv.lengths[s])
                    kv.committed(s)
                    kv.seal_prompt_page(s, prompt)
                    if fed_pos < len(prompt) - 1:
                        slot_feed[s] = prompt[fed_pos + 1]   # prefill replay
                        slot_decode[s] = False
                        continue
                    done = False                             # decode sample
                    if sync:
                        t_s = int(sampled[s])
                        done = t_s == eos_id
                        if not done:
                            slot_gen[s].append(t_s)
                            slot_feed[s] = t_s
                    else:
                        slot_gen[s].append((step_idx, s))    # resolved at end
                    if not done:
                        self.tokens_generated += 1
                        slot_decode[s] = True
                        done = (len(slot_gen[s]) >= max_new_tokens
                                or int(kv.lengths[s]) >= kv.capacity)
                    if done:
                        results[rid] = slot_gen[s]
                        kv.retire(s)
                        slot_req[s] = -1
                        slot_feed[s] = 0
                        slot_decode[s] = False
        if hist:
            all_samples = np.asarray(jnp.stack(hist))        # the ONE pull
            results = [np.asarray([all_samples[i, s] for i, s in r], np.int32)
                       for r in results]
        else:
            results = [np.asarray(r, np.int32) for r in results]
        if prev is not None:
            jax.block_until_ready(prev)
        self.decode_time += time.time() - t0
        return results

    MAX_WINDOW = 32

    def _serve_windowed(self, prompts, max_new_tokens: int) -> list[np.ndarray]:
        """The greedy/no-EOS fast path of :meth:`serve`: W-step windows.

        Without EOS the whole schedule depends only on LENGTHS, which the
        host knows in advance — so between scheduling events (a slot
        retiring, a request admitted) there is nothing to decide per step.
        The engine picks the window W = steps until the next retirement
        (quantized to powers of two, capped at ``MAX_WINDOW`` so at most
        log₂ variants compile), pre-allocates every page the window
        touches, and runs all W steps in one compiled ``lax.scan`` —
        per-token host overhead is amortized W-fold and samples come back
        in one (W, B) array per window, pulled once at the very end.
        """
        results: list = [None] * len(prompts)
        queue = deque(range(len(prompts)))
        kv = self.kv
        slot_req = np.full(self.B, -1, np.int64)
        slot_gen: list[list] = [[] for _ in range(self.B)]
        hist: list = []                          # (W, B) device samples
        base = 0                                 # global step index of window
        dev_table, tver = None, -1
        prev = jnp.zeros(self.B, jnp.int32)
        t0 = time.time()
        with _backend_scope(self.backend, self._mesh):
            while queue or (slot_req >= 0).any():
                for s in range(self.B):          # admission into free slots
                    if slot_req[s] < 0 and queue:
                        rid = queue.popleft()
                        kv.admit(s, prompts[rid])
                        if self.api.has_recurrent_state:
                            self.caches = self._reset_slot(self.caches, s)
                        slot_req[s] = rid
                        slot_gen[s] = []
                occ = np.nonzero(slot_req >= 0)[0]
                # window = steps until the FIRST slot must retire
                horizon = self.MAX_WINDOW
                for s in occ:
                    pr = prompts[slot_req[s]]
                    stop = min(len(pr) - 1 + max_new_tokens, kv.capacity)
                    horizon = min(horizon, stop - int(kv.lengths[s]))
                W = 1 << (int(horizon).bit_length() - 1)     # quantize down
                feed = np.zeros((W, self.B), np.int32)
                use_prev = np.zeros((W, self.B), bool)
                for s in occ:
                    pr = prompts[slot_req[s]]
                    t = int(kv.lengths[s])
                    for op in kv.prepare_window(int(s), W):
                        self.caches = self._copy(self.caches, op.src, op.dst)
                    n_pref = max(0, min(W, len(pr) - t))     # prompt feeds
                    feed[:n_pref, s] = pr[t:t + n_pref]
                    use_prev[n_pref:, s] = True              # then self-feed
                if kv.version != tver:
                    dev_table = jnp.asarray(kv.table.copy())
                    tver = kv.version
                samples, self.caches = self._wstep(
                    self.params, self.caches, jnp.asarray(feed),
                    jnp.asarray(use_prev), prev, dev_table,
                    jnp.asarray(kv.lengths.copy()),
                    jnp.asarray((slot_req >= 0).astype(np.int32)))
                prev = samples[-1]
                hist.append(samples)
                self.serve_steps += W
                for s in occ:
                    rid = int(slot_req[s])
                    pr = prompts[rid]
                    old = int(kv.lengths[s])
                    kv.committed(int(s), W)
                    kv.seal_prompt_pages(int(s), pr, old)
                    gen0 = min(W, max(0, len(pr) - 1 - old))  # 1st decode step
                    for i in range(gen0, W):
                        slot_gen[s].append((base + i, s))
                    self.tokens_generated += W - gen0
                    if (len(slot_gen[s]) >= max_new_tokens
                            or old + W >= kv.capacity):
                        results[rid] = slot_gen[s]
                        kv.retire(int(s))
                        slot_req[s] = -1
                base += W
        if hist:                                 # the ONE device→host pull
            allv = np.concatenate([np.asarray(h) for h in hist])
            results = [np.asarray([allv[i, s] for i, s in r], np.int32)
                       for r in results]
        self.decode_time += time.time() - t0
        return results

    @property
    def tokens_per_second(self) -> float:
        return self.tokens_generated / max(self.decode_time, 1e-9)


class GeometryEngine:
    """Batched inference over ragged point clouds (the pointcloud family).

    Requests are (points, feats) pairs of ANY sizes; the engine owns the
    whole ragged pipeline: per-cloud ball-tree permutation → pack to a
    bucketed length with per-sample masks → one jitted batched forward →
    unpack + inverse-permute.  Clouds are served in request order, grouped
    into batches of ``batch_slots``.

    Two batch LAYOUTS (docs/varlen.md):

    * ``"packed"`` (default when the model runs BSA) — clouds concatenated
      on ONE packed axis with an ``offsets`` boundary array
      (``core.balltree.pack_varlen``); no dummy batch slots, no
      per-slot padding to the largest cloud, so the forward spends FLOPs
      proportional to Σnᵢ rather than B·max(nᵢ).
    * ``"padded"`` — the classic (B, L, ·) bucket-padded batch with
      per-sample masks; required for non-BSA attention mechanisms, whose
      layers don't take offsets.

    With ``backend="sharded"`` the ``"packed"`` layout's offsets reach the
    varlen ops as TRACED values (they are jitted batch data here), so the
    host-side LPT segment planner cannot run and those ops warn once and
    fall back to the inner backend unsharded — by design; use the
    ``"padded"`` layout (ring-sharded dense ops) when mesh scaling of
    geometry serving matters.  See docs/distributed.md.

    ``pad_to`` freezes the compiled length (use the dataset's
    ``max_padded_len`` when the size range is known): the per-slot padded
    length in ``"padded"`` layout, the TOTAL packed capacity in
    ``"packed"``.  Otherwise each batch pads to a geometric bucket (of the
    largest cloud, resp. of the packed total), giving at most
    O(log size-range) compilations.  A short final batch costs nothing
    extra when packed (offsets simply repeat); padded layout fills it with
    fully-masked dummy slots rather than recompiling at a smaller B.
    """

    def __init__(self, api, params, *, batch_slots: int = 8,
                 pad_to: int | None = None, backend: str | None = None,
                 layout: str | None = None):
        self.api = api
        self.params = params
        self.batch_slots = batch_slots
        self.pad_to = pad_to
        self.backend = backend          # attention-backend override (by name)
        self._mesh = _require_mesh_if_needed(backend, api, "GeometryEngine")
        if layout is None:
            layout = "packed" if api.mcfg.attention == "bsa" else "padded"
        if layout not in ("packed", "padded"):
            raise ValueError(f"layout must be 'packed' or 'padded', got {layout!r}")
        self.layout = layout
        self.ball_size = api.mcfg.bsa.ball_size
        self._fwd = jax.jit(api.forward)
        self.clouds_served = 0
        self.points_served = 0
        self.predict_time = 0.0

    def predict(self, clouds) -> list[np.ndarray]:
        """clouds: sequence of ``(points (n_i, d), feats (n_i, in_dim))``
        pairs (or dicts with those keys).  Returns one (n_i, out_dim) array
        per cloud, rows in the CALLER's original point order."""
        clouds = [(c["points"], c["feats"]) if isinstance(c, dict) else c
                  for c in clouds]
        results: list[np.ndarray] = []
        t0 = time.time()
        for s in range(0, len(clouds), self.batch_slots):
            results.extend(self._predict_batch(clouds[s:s + self.batch_slots]))
        self.predict_time += time.time() - t0
        self.clouds_served += len(clouds)
        self.points_served += sum(int(np.asarray(p).shape[0]) for p, _ in clouds)
        return results

    def _predict_batch(self, chunk) -> list[np.ndarray]:
        pts_list = [np.asarray(p) for p, _ in chunk]
        fts_list = [np.asarray(f, np.float32) for _, f in chunk]
        perms = build_balltree_permutations(pts_list, self.ball_size)
        ordered = [f[perm] for f, perm in zip(fts_list, perms)]
        if self.layout == "packed":
            feats, offsets, mask = pack_varlen(
                ordered, self.ball_size, pad_to=self.pad_to,
                max_samples=self.batch_slots)
            with _backend_scope(self.backend, self._mesh):
                pred = self._fwd(self.params,
                                 {"feats": jnp.asarray(feats)[None],
                                  "mask": jnp.asarray(mask)[None],
                                  "offsets": jnp.asarray(offsets)})
            per_cloud = unpack_varlen(np.asarray(pred)[0],
                                      offsets[:len(chunk) + 1], mask)
            out = []
            for rows, perm in zip(per_cloud, perms):
                unperm = np.empty_like(rows)
                unperm[perm] = rows                # ball order → original order
                out.append(unperm)
            return out
        target = self.pad_to or bucket_length(
            max(f.shape[0] for f in ordered), self.ball_size)
        # fully-masked dummy slots keep B static for the final short batch
        # (every branch returns exact zeros for an all-invalid sample)
        pad_slots = self.batch_slots - len(chunk)
        if pad_slots > 0:
            ordered += [np.zeros((1, ordered[0].shape[1]), np.float32)] * pad_slots
        feats, mask = pack_ragged(ordered, self.ball_size, pad_to=target)
        if pad_slots > 0:
            mask[len(chunk):] = False
        with _backend_scope(self.backend, self._mesh):
            pred = self._fwd(self.params, {"feats": jnp.asarray(feats),
                                           "mask": jnp.asarray(mask)})
        per_cloud = unpack_ragged(np.asarray(pred), mask)[:len(chunk)]
        out = []
        for rows, perm in zip(per_cloud, perms):
            unperm = np.empty_like(rows)
            unperm[perm] = rows                    # ball order → original order
            out.append(unperm)
        return out

    @property
    def points_per_second(self) -> float:
        return self.points_served / max(self.predict_time, 1e-9)
