from repro.serving.engine import ServingEngine, GeometryEngine  # noqa: F401
