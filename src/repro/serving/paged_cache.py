"""Paged KV cache: block-pool allocator, hash-chained prefix tree, and the
per-slot controller that ``ServingEngine`` drives (docs/serving.md).

The cache is split HOST/DEVICE:

* Device side (inside the jitted decode step) there are only flat KV POOLS —
  ``(num_blocks + 1) * page`` token rows shared by every slot — plus the
  matching φ-compressed pools.  The step reads/writes them through a
  ``(B, n_pages)`` int32 BLOCK TABLE and a ``(B,)`` per-slot length vector
  (``core.nsa_causal.nsa_causal_decode_paged``).  The final block is the
  TRASH block: inactive slots' writes and unallocated-page reads are routed
  there, so the step never needs data-dependent shapes.
* Host side (this module) lives all allocation POLICY: a free-list
  :class:`BlockAllocator` with per-block refcounts, per-slot block tables and
  lengths as numpy arrays (pushed to the step as arguments each call — they
  are tiny), and a :class:`PrefixCache` tree keyed by hash-chained token
  pages so identical prompt prefixes REUSE cached blocks across requests.

Invariants (pinned by tests/test_paged_properties.py):

* every block is either on the free list or refcounted > 0 — never both,
  never neither (no leaks, no double-free);
* a block's refcount equals the number of live references: slot table
  entries pointing at it plus prefix-tree nodes holding it;
* a prefix-tree lookup returns a block only for an exact token-prefix match
  (hash-chained SHA-256 over (parent chain, page tokens));
* shared blocks are never written: a slot that must write into a block with
  refcount > 1 first COPIES it (copy-on-write) — the controller emits the
  copy as a host op the engine applies to the device pools.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = ["BlockAllocator", "PrefixCache", "PagedKVCache", "CopyOp"]


class BlockAllocator:
    """Fixed-pool free-list allocator with per-block refcounts.

    Blocks are ints in ``[0, num_blocks)``.  ``alloc`` returns a block with
    refcount 1 (or None when exhausted); ``incref``/``decref`` manage
    sharing, and a block returns to the free list exactly when its count
    hits zero.
    """

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = np.zeros(num_blocks, np.int64)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live_count(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, block: int) -> int:
        return int(self._ref[block])

    def alloc(self) -> int | None:
        if not self._free:
            return None
        b = self._free.pop()
        assert self._ref[b] == 0, f"block {b} on free list with refcount {self._ref[b]}"
        self._ref[b] = 1
        return b

    def incref(self, block: int) -> int:
        if self._ref[block] <= 0:
            raise RuntimeError(f"incref on free block {block}")
        self._ref[block] += 1
        return int(self._ref[block])

    def decref(self, block: int) -> int:
        if self._ref[block] <= 0:
            raise RuntimeError(f"double free of block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
        return int(self._ref[block])

    def check(self) -> None:
        """Assert the no-leak invariant (free + referenced == all blocks)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate blocks on free list"
        for b in range(self.num_blocks):
            held = self._ref[b] > 0
            assert held != (b in free), (
                f"block {b}: refcount {self._ref[b]}, on_free={b in free}")


class _PrefixNode:
    __slots__ = ("key", "block", "parent", "children", "last_used")

    def __init__(self, key: bytes, block: int, parent: "_PrefixNode | None"):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: dict[bytes, _PrefixNode] = {}
        self.last_used = 0


class PrefixCache:
    """Hash-chained prefix tree: one node per FULL prompt page.

    Page ``i`` of a prompt is keyed by ``h_i = sha256(h_{i-1} || tokens of
    page i)`` — the chain makes the key depend on the whole prefix, so two
    different prefixes can never collide on a node (modulo SHA-256).  Each
    node holds one block id and one allocator reference; lookups touch nodes
    (LRU clock) and :meth:`evict_lru` releases cold LEAF nodes when the pool
    runs dry.
    """

    def __init__(self, allocator: BlockAllocator, page: int):
        self.allocator = allocator
        self.page = page
        self._root = _PrefixNode(b"", -1, None)
        self._nodes: dict[bytes, _PrefixNode] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self._nodes)

    @staticmethod
    def _chain(prev: bytes, chunk: np.ndarray) -> bytes:
        h = hashlib.sha256()
        h.update(prev)
        h.update(np.ascontiguousarray(chunk, np.int32).tobytes())
        return h.digest()

    def chain_keys(self, tokens: np.ndarray) -> list[bytes]:
        """Chain hash key per full page of ``tokens``."""
        keys, prev = [], b""
        for i in range(len(tokens) // self.page):
            prev = self._chain(prev, tokens[i * self.page:(i + 1) * self.page])
            keys.append(prev)
        return keys

    def lookup(self, tokens: np.ndarray) -> list[int]:
        """Blocks caching the longest full-page prefix of ``tokens``.

        Does NOT take references — the caller increfs the blocks it actually
        uses.  Touches the returned nodes' LRU clocks.
        """
        self._clock += 1
        node, blocks = self._root, []
        for key in self.chain_keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._clock
            blocks.append(child.block)
            node = child
        return blocks

    def insert(self, tokens: np.ndarray, page_idx: int, block: int) -> bool:
        """Register ``block`` as the cache of page ``page_idx`` of ``tokens``.

        All earlier pages must already be in the tree (prompts are sealed
        in order).  Takes ONE allocator reference on behalf of the tree.
        Returns False (and takes no reference) if the node already exists —
        first writer wins.
        """
        keys = self.chain_keys(tokens)
        if page_idx >= len(keys):
            raise ValueError(f"page {page_idx} not a full page of {len(tokens)} tokens")
        node = self._root
        for key in keys[:page_idx]:
            node = node.children[key]        # KeyError ⇒ out-of-order seal (bug)
        key = keys[page_idx]
        if key in node.children:
            return False
        self._clock += 1
        child = _PrefixNode(key, block, node)
        child.last_used = self._clock
        node.children[key] = child
        self._nodes[key] = child
        self.allocator.incref(block)
        return True

    def evict_lru(self, n_blocks: int = 1) -> int:
        """Drop up to ``n_blocks`` least-recently-used LEAF nodes, releasing
        their tree references.  Returns how many were dropped (a block only
        actually frees when no slot still references it)."""
        dropped = 0
        while dropped < n_blocks:
            leaves = [nd for nd in self._nodes.values() if not nd.children]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            victim.parent.children.pop(victim.key)
            del self._nodes[victim.key]
            self.allocator.decref(victim.block)
            dropped += 1
        return dropped

    def clear(self) -> None:
        while self._nodes:
            self.evict_lru(len(self._nodes))


@dataclasses.dataclass(frozen=True)
class CopyOp:
    """Device-pool copy the engine must apply: block ``src`` → ``dst``
    (token rows ``[src*page, (src+1)*page)`` and the matching compressed
    rows).  Emitted by copy-on-write and never reordered across a step."""

    src: int
    dst: int


class PagedKVCache:
    """Host-side controller for one engine: allocator + tables + prefix tree.

    ``n_slots`` fixed decode slots share ``num_blocks`` pool blocks of
    ``page`` tokens each; a slot may hold at most ``n_pages`` pages
    (``capacity == n_pages * page`` tokens).  The TRASH block id is
    ``num_blocks`` — the device pools carry one extra block for it, and
    unallocated table entries point there.
    """

    def __init__(self, *, n_slots: int, num_blocks: int, page: int,
                 n_pages: int, prefix_cache: bool = True):
        if page <= 0 or n_pages <= 0:
            raise ValueError("page and n_pages must be positive")
        self.n_slots = n_slots
        self.page = page
        self.n_pages = n_pages
        self.trash = num_blocks
        self.allocator = BlockAllocator(num_blocks)
        self.prefix = PrefixCache(self.allocator, page) if prefix_cache else None
        self.table = np.full((n_slots, n_pages), self.trash, np.int32)
        self.lengths = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self.version = 0                 # bumped on every TABLE mutation, so
        self.blocks_reused = 0           # the engine re-pushes the device
        self.cow_copies = 0              # copy only when it actually changed

    @property
    def capacity(self) -> int:
        return self.n_pages * self.page

    # -- allocation with prefix-tree backpressure ---------------------------

    def _alloc(self) -> int:
        b = self.allocator.alloc()
        while b is None and self.prefix is not None and len(self.prefix):
            if not self.prefix.evict_lru(1):
                break
            b = self.allocator.alloc()
        if b is None:
            raise RuntimeError(
                f"KV pool exhausted: {self.allocator.num_blocks} blocks of "
                f"{self.page} tokens all referenced — raise num_blocks or "
                "lower concurrency")
        return b

    def _slot_pages(self, slot: int) -> int:
        """Pages currently referenced by ``slot`` (covering its length; the
        page being written counts as soon as any token landed in it)."""
        return -(-int(self.lengths[slot]) // self.page)

    # -- slot lifecycle -----------------------------------------------------

    def admit(self, slot: int, prompt: np.ndarray) -> int:
        """Claim ``slot`` for a new request.  Looks the prompt up in the
        prefix tree and reuses every cached full page strictly below the
        last prompt position (the final position must be recomputed: its
        step produces the logits that sample the first generated token).
        Returns the number of prompt tokens already served from cache."""
        assert not self.active[slot], f"slot {slot} still active"
        assert self.table[slot, 0] == self.trash, f"slot {slot} not retired"
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) > self.capacity:
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds slot "
                             f"capacity {self.capacity}")
        reused = 0
        if self.prefix is not None:
            blocks = self.prefix.lookup(prompt)
            reused = min(len(blocks) * self.page, len(prompt) - 1)
            n_ref = -(-reused // self.page)          # pages covering [0, reused)
            for p in range(n_ref):
                self.allocator.incref(blocks[p])
                self.table[slot, p] = blocks[p]
            self.blocks_reused += n_ref
        self.lengths[slot] = reused
        self.active[slot] = True
        self.version += 1
        return reused

    def retire(self, slot: int) -> None:
        """Release the slot: drop its table references (blocks still held by
        the prefix tree or other slots survive) and mark it free."""
        for p in range(self._slot_pages(slot)):
            self.allocator.decref(int(self.table[slot, p]))
        self.table[slot] = self.trash
        self.lengths[slot] = 0
        self.active[slot] = False
        self.version += 1

    def fork(self, dst: int, src: int) -> None:
        """Clone ``src``'s sequence into free slot ``dst`` by sharing every
        block (incref, no copy).  The first write either side makes into a
        shared page copy-on-writes it apart."""
        assert not self.active[dst] and self.active[src]
        for p in range(self._slot_pages(src)):
            b = int(self.table[src, p])
            self.allocator.incref(b)
            self.table[dst, p] = b
        self.lengths[dst] = self.lengths[src]
        self.active[dst] = True
        self.version += 1

    # -- per-step page management -------------------------------------------

    def prepare_window(self, slot: int, n: int) -> list[CopyOp]:
        """Make positions ``[lengths[slot], lengths[slot]+n)`` writable
        before an n-step decode window.

        Copy-on-writes the tail page when it is shared (refcount > 1 — e.g.
        a fully-cached prompt whose last position must be recomputed, or a
        forked slot) and allocates a fresh block for every later page the
        window touches.  Returns the device copies the engine must apply to
        every layer's pools.
        """
        assert self.active[slot]
        t = int(self.lengths[slot])
        if t + n > self.capacity:
            raise RuntimeError(f"slot {slot} overflow: window [{t}, {t + n}) "
                               f"exceeds capacity {self.capacity}")
        p_last = (t + n - 1) // self.page
        ops: list[CopyOp] = []
        if t % self.page:                        # partially-written tail page
            pg = t // self.page
            src = int(self.table[slot, pg])
            if self.allocator.refcount(src) > 1:
                dst = self._alloc()
                ops.append(CopyOp(src=src, dst=dst))
                self.allocator.decref(src)
                self.table[slot, pg] = dst
                self.cow_copies += 1
                self.version += 1
            p_first = pg + 1
        else:
            p_first = t // self.page
        for pg in range(p_first, p_last + 1):
            assert self.table[slot, pg] == self.trash, \
                f"slot {slot} page {pg} already mapped at its first token"
            self.table[slot, pg] = self._alloc()
            self.version += 1
        return ops

    def prepare_append(self, slot: int) -> list[CopyOp]:
        """Make position ``lengths[slot]`` writable (one-step window)."""
        return self.prepare_window(slot, 1)

    def committed(self, slot: int, n: int = 1) -> None:
        """Account ``n`` tokens written from ``lengths[slot]`` (post-step)."""
        self.lengths[slot] += n

    def seal_prompt_pages(self, slot: int, prompt: np.ndarray,
                          prev_len: int) -> int:
        """Publish every page that filled ENTIRELY with prompt tokens while
        the slot advanced from ``prev_len`` to ``lengths[slot]``, so later
        requests reuse it.  Returns how many pages were newly inserted
        (existing nodes win; no-op when prefix caching is off)."""
        if self.prefix is None:
            return 0
        last = min(int(self.lengths[slot]), len(prompt))
        first = prev_len - prev_len % self.page + self.page   # > prev_len
        sealed = 0
        for m in range(first, last + 1, self.page):
            pg = m // self.page - 1
            sealed += bool(self.prefix.insert(prompt[:m], pg,
                                              int(self.table[slot, pg])))
        return sealed

    def seal_prompt_page(self, slot: int, prompt: np.ndarray) -> bool:
        """One-step variant: seal the page ending exactly at ``lengths``."""
        return self.seal_prompt_pages(slot, prompt,
                                      int(self.lengths[slot]) - 1) > 0

    def check(self) -> None:
        """Assert refcounts == live references (slots + tree)."""
        refs = np.zeros(self.allocator.num_blocks, np.int64)
        for s in range(self.n_slots):
            for p in range(self._slot_pages(s)):
                refs[int(self.table[s, p])] += 1
        if self.prefix is not None:
            for nd in self.prefix._nodes.values():
                refs[nd.block] += 1
        for b in range(self.allocator.num_blocks):
            assert refs[b] == self.allocator.refcount(b), (
                f"block {b}: {refs[b]} live references vs refcount "
                f"{self.allocator.refcount(b)}")
        self.allocator.check()
