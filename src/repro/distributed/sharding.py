"""Logical-axis sharding (MaxText-style activation partitioning).

Models annotate activations with LOGICAL axis names (``constrain(x, "batch",
"seq", "d_model")``); a context maps logical names to mesh axes.  Outside any
context (unit tests, single-device smoke runs) ``constrain`` is a no-op, so
models never depend on a mesh being present.

Divisibility guard: a logical axis is only mapped if the dimension is
divisible by the mesh-axis size — e.g. llava-next's 56 heads on a 16-way
``model`` axis fall back to replicated heads (the FFN still shards; see
DESIGN §4 and the ``sequence`` attn_shard_mode).
"""

from __future__ import annotations

import contextlib
import threading
import warnings

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# (logical name, mesh axes, dim, size) combos already warned about — the
# divisibility fallback fires once per distinct cause, not once per trace
_WARNED_REPLICATION: set = set()

# logical axis -> mesh axis name(s); None = replicate
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    # residual stream BETWEEN layers: sharded over `model` (Megatron sequence
    # parallelism) so the scan-carried activations the remat policy saves are
    # 1/TP the size; GSPMD inserts the AG/RS pairs around TP matmuls.
    "seq_res": ("model",),
    "seq_sp": ("data",),          # sequence-parallel mode (long_500k, batch < data)
    "seq_model": ("model",),      # ball-parallel attention (attn_shard_mode=sequence)
    "d_model": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "d_ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "capacity": ("pod", "data"),  # MoE dispatch buffer token dim over DP
    "ssm_inner": ("model",),
    "ssm_state": None,
    "blocks": None,
    "stage": ("stage",),
}


def reset_replication_warnings() -> None:
    """Clear the one-shot divisibility-warning registry (test isolation —
    pairs with ``sharded_backend.reset_warnings``)."""
    _WARNED_REPLICATION.clear()


def _get():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict | None = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    prev = _get()
    _STATE.ctx = (mesh, merged)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_rules():
    return _get()


def logical_to_spec(logical_axes, shape, mesh, rules) -> P:
    """Map logical axis names to a PartitionSpec, respecting divisibility."""
    spec = []
    used = set()
    for dim, name in zip(shape, logical_axes):
        entry = rules.get(name) if name else None
        if entry is None:
            spec.append(None)
            continue
        axes = tuple(a for a in (entry if isinstance(entry, tuple) else (entry,))
                     if a in mesh.shape and a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 0 and dim % size == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            if axes and dim % size != 0:
                # the used-axis fallback (axes filtered to empty) is
                # structural and silent; a DIVISIBILITY miss is usually a
                # shape bug, so name the culprit once
                key = (name, axes, dim, size)
                if key not in _WARNED_REPLICATION:
                    _WARNED_REPLICATION.add(key)
                    warnings.warn(
                        f"logical axis {name!r} (dim {dim}) is not divisible "
                        f"by mesh axes {axes} (size {size}); replicating "
                        f"instead of sharding", RuntimeWarning, stacklevel=2)
            spec.append(None)
    return P(*spec)


def constrain(x, *logical_axes):
    """Annotate activation sharding; no-op outside an ``axis_rules`` context."""
    ctx = _get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
