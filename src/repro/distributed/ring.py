"""Ring context parallelism: ``lax.ppermute`` K/V rotation primitives.

The sharded backend's three remaining fallbacks (token-causal flash,
replicated-K/V selection, unsharded packed-varlen) all reduce to the same
missing primitive: attention where the QUERIES stay put and the KEYS/VALUES
travel.  This module provides it in three shapes:

* :func:`ring_flash` — dense flash attention with every operand sequence-
  sharded.  Each of the ``p`` hops attends the resident K/V slab, merges the
  partial result into running online-softmax statistics ``(m, l, acc)``, and
  rotates the slab to the right neighbour (``lax.ppermute``).  Per-shard K/V
  memory is O(L/p); the all-gather of the replicated path never happens.
  A hand-written ``jax.custom_vjp`` keeps the kernels' residual contract:
  the backward saves only ``(out, lse)`` and RECOMPUTES each hop's
  probabilities from the logsumexp while the K/V slabs (and the travelling
  dK/dV accumulators) make one more full revolution — so backward memory is
  O(L/p) too, exactly like the fused Pallas backwards.
* **Causal hop skipping** — with token-causal masking, hop ``h`` on shard
  ``i`` brings the slab of source shard ``(i - h) mod p``, which is entirely
  in shard ``i``'s future whenever ``h > i``.  The static ``(p, p)`` live
  table from :func:`repro.kernels.occupancy.ring_hop_live` (the tile
  liveness math at hop granularity) gates each hop's compute behind
  ``lax.cond`` — the rotation itself still runs on every shard (it is a
  collective), but dead hops issue no matmuls, so the causal ring does
  ``p(p+1)/2`` of ``p²`` hop-computations (~half the work).
* :func:`ring_selection` — the selection branch with K/V *sharded*: top-k
  block indices are re-based to ring-local coordinates each hop
  (``loc = top_idx − src·nb_loc``); a hop attends only the selected blocks
  resident on the current slab, and hops that hold none of a shard's
  selections are skipped at runtime (``lax.cond`` on ``any(here)``).  Exact
  because every global block lives on exactly one shard, so the per-hop
  partials partition each group's selected set.  Differentiated by plain
  autodiff under one outer ``jax.checkpoint`` — the backward replays the
  whole ring (rotations included) instead of saving per-hop gathered
  blocks.

Plus the host-side planner for segment-sharded packed-varlen batches:

* :func:`plan_segments` / :class:`SegmentPlan` — greedy LPT (longest
  processing time) partitioning of samples onto shards with cost ∝ nᵢ²
  (attention work is quadratic per sample), and :func:`axis_layout` /
  :func:`split_tokens` / :func:`merge_tokens` to re-lay the packed axis out
  as one contiguous padded slab per shard.  After the re-layout every BSA
  branch is segment-local (samples never attend each other), so the varlen
  ops run per shard with plain local offsets and ZERO collectives — the
  compression branch's ring degenerates to its hop-0 term because the
  pooled key axis is laid out with the same sample→shard assignment.
  Plans and layouts are LRU-cached on the concrete offsets.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.numerics import NEG_INF, mask_to_bias

__all__ = [
    "ring_perm",
    "ring_flash",
    "ring_selection",
    "SegmentPlan",
    "plan_segments",
    "lpt_partition",
    "round_robin_partition",
    "axis_layout",
    "split_tokens",
    "merge_tokens",
]

_TINY = 1e-20


def ring_perm(p: int) -> list[tuple[int, int]]:
    """The rotation permutation: shard j sends to (j+1) mod p, so after one
    ``ppermute`` shard i holds what its LEFT neighbour held — hop h leaves
    shard i holding the slab originated by shard (i − h) mod p."""
    return [(j, (j + 1) % p) for j in range(p)]


def _rotate(xs, axis, p):
    perm = ring_perm(p)
    return tuple(jax.lax.ppermute(x, axis, perm) for x in xs)


def _merge(m, l, acc, m_h, l_h, acc_h):
    """Online-softmax merge of two partial-attention statistics triples.

    m: running row max (…); l: running sum of exp (…); acc: running
    unnormalised output (…, D).  All-masked partials carry m = NEG_INF (or
    below) and l = 0, so they merge as exact no-ops."""
    m_new = jnp.maximum(m, m_h)
    a = jnp.exp(m - m_new)
    b = jnp.exp(m_h - m_new)
    return (m_new, a * l + b * l_h,
            a[..., None] * acc + b[..., None] * acc_h)


# ---------------------------------------------------------------------------
# ring flash — dense flash attention over rotating K/V slabs
# ---------------------------------------------------------------------------

def _flash_partial(qh, kh, vh, bias, rep):
    """One hop's partial stats.  qh (B,Hq,n,D) vs head-major slab kh/vh
    (B,Hkv,n,D); bias broadcastable to (B,1,n,n).  Returns fp32
    (m (B,Hq,n), l (B,Hq,n), acc (B,Hq,n,D))."""
    d = qh.shape[-1]
    if rep > 1:
        kh = jnp.repeat(kh, rep, axis=1)
        vh = jnp.repeat(vh, rep, axis=1)
    logits = jnp.einsum("bhnd,bhld->bhnl", qh, kh,
                        preferred_element_type=jnp.float32) / (d ** 0.5)
    logits = logits + bias
    m = logits.max(-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(logits <= NEG_INF / 2, 0.0, p)
    l = p.sum(-1)
    acc = jnp.einsum("bhnl,bhld->bhnd", p, vh.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return m, l, acc


def _hop_bias(kbias, i, src, n, causal):
    """(B, n) travelling key bias + the token-causal rule from GLOBAL
    positions (query shard i, key source shard src)."""
    bias = kbias[:, None, None, :]                          # (B,1,1,n)
    if causal:
        qpos = i * n + jnp.arange(n)
        kpos = src * n + jnp.arange(n)
        bias = bias + mask_to_bias(kpos[None, :] <= qpos[:, None])[None, None]
    return bias


@functools.lru_cache(maxsize=64)
def _ring_flash_fn(axis: str, p: int, causal: bool, live_key):
    """Build (and cache) the custom_vjp ring-flash core for one static
    configuration.  ``live_key``: hashable (p, p) hop-live table (rows =
    shard, cols = hop) or None = every hop computes."""
    live = None if live_key is None else np.asarray(live_key, bool)

    def _gated(pred, fn, carry):
        if pred is None:
            return fn(carry)
        return jax.lax.cond(pred, fn, lambda c: c, carry)

    def _hop_pred(i, h):
        if live is None:
            return None
        return jnp.asarray(live)[i, h]

    def _fwd_stats(q, k, v, kbias):
        B, n, Hq, D = q.shape
        rep = Hq // k.shape[2]
        i = jax.lax.axis_index(axis)
        qh = q.transpose(0, 2, 1, 3)
        kc = k.transpose(0, 2, 1, 3)
        vc = v.transpose(0, 2, 1, 3)
        bc = kbias
        m = jnp.full((B, Hq, n), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hq, n), jnp.float32)
        acc = jnp.zeros((B, Hq, n, D), jnp.float32)
        for h in range(p):
            src = (i - h) % p
            bias = _hop_bias(bc, i, src, n, causal)

            def hop(carry, kh=kc, vh=vc, bias=bias):
                mh, lh, ah = _flash_partial(qh, kh, vh, bias, rep)
                return _merge(*carry, mh, lh, ah)

            m, l, acc = _gated(_hop_pred(i, h), hop, (m, l, acc))
            if h < p - 1:
                kc, vc, bc = _rotate((kc, vc, bc), axis, p)
        out = acc / jnp.maximum(l, _TINY)[..., None]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, _TINY)), NEG_INF)
        return out.transpose(0, 2, 1, 3), lse               # out core-layout

    @jax.custom_vjp
    def f(q, k, v, kbias):
        out, _ = _fwd_stats(q, k, v, kbias)
        return out.astype(v.dtype)

    def f_fwd(q, k, v, kbias):
        out, lse = _fwd_stats(q, k, v, kbias)
        return out.astype(v.dtype), (q, k, v, kbias, out, lse)

    def f_bwd(res, do):
        q, k, v, kbias, out, lse = res
        B, n, Hq, D = q.shape
        Hkv = k.shape[2]
        rep = Hq // Hkv
        i = jax.lax.axis_index(axis)
        qh = q.transpose(0, 2, 1, 3).astype(jnp.float32)
        doh = do.transpose(0, 2, 1, 3).astype(jnp.float32)
        delta = (doh * out.transpose(0, 2, 1, 3)).sum(-1)   # (B,Hq,n)
        scale = 1.0 / (D ** 0.5)
        kc = k.transpose(0, 2, 1, 3)
        vc = v.transpose(0, 2, 1, 3)
        bc = kbias
        dq = jnp.zeros((B, Hq, n, D), jnp.float32)
        dk = jnp.zeros((B, Hkv, n, D), jnp.float32)
        dv = jnp.zeros((B, Hkv, n, D), jnp.float32)
        for h in range(p):
            src = (i - h) % p
            bias = _hop_bias(bc, i, src, n, causal)

            def hop(carry, kh=kc, vh=vc, bias=bias):
                dq, dk, dv = carry
                khr = jnp.repeat(kh, rep, axis=1) if rep > 1 else kh
                vhr = jnp.repeat(vh, rep, axis=1) if rep > 1 else vh
                logits = jnp.einsum(
                    "bhnd,bhld->bhnl", qh, khr,
                    preferred_element_type=jnp.float32) * scale + bias
                ph = jnp.exp(logits - lse[..., None])
                ph = jnp.where(logits <= NEG_INF / 2, 0.0, ph)
                dp = jnp.einsum("bhnd,bhld->bhnl", doh,
                                vhr.astype(jnp.float32),
                                preferred_element_type=jnp.float32)
                dl = ph * (dp - delta[..., None])
                dq2 = dq + jnp.einsum("bhnl,bhld->bhnd", dl,
                                      khr.astype(jnp.float32)) * scale
                dkh = jnp.einsum("bhnl,bhnd->bhld", dl, qh) * scale
                dvh = jnp.einsum("bhnl,bhnd->bhld", ph, doh)
                if rep > 1:
                    dkh = dkh.reshape(B, Hkv, rep, n, D).sum(2)
                    dvh = dvh.reshape(B, Hkv, rep, n, D).sum(2)
                return dq2, dk + dkh, dv + dvh

            dq, dk, dv = _gated(_hop_pred(i, h), hop, (dq, dk, dv))
            # rotate EVERY iteration (p total): the slab — and the dK/dV it
            # accumulated while visiting — completes the revolution home
            kc, vc, bc, dk, dv = _rotate((kc, vc, bc, dk, dv), axis, p)
        return (dq.transpose(0, 2, 1, 3).astype(q.dtype),
                dk.transpose(0, 2, 1, 3).astype(k.dtype),
                dv.transpose(0, 2, 1, 3).astype(v.dtype),
                jnp.zeros_like(kbias))

    f.defvjp(f_fwd, f_bwd)
    return f


def ring_flash(q, k, v, kbias, *, axis: str, p: int, causal: bool = False,
               live=None):
    """Sequence-sharded flash attention (call INSIDE shard_map).

    q (B, n, Hq, D), k/v (B, n, Hkv, D), kbias (B, n) fp32 additive key bias
    (0 = attend, NEG_INF = masked) — all LOCAL slabs of a length-p·n global
    sequence sharded along mesh axis ``axis``.  ``causal`` applies the
    token-causal rule on GLOBAL positions; ``live`` is an optional (p, p)
    hop-live table (see ``occupancy.ring_hop_live``) gating per-hop compute.
    Returns the local (B, n, Hq, D) output slab.  Differentiable in q/k/v
    (kbias gets zero cotangent) with O(n) backward memory via per-hop
    recompute from the saved logsumexp."""
    live_key = None
    if live is not None:
        live_key = tuple(tuple(bool(x) for x in row)
                         for row in np.asarray(live))
    return _ring_flash_fn(axis, p, bool(causal), live_key)(q, k, v, kbias)


# ---------------------------------------------------------------------------
# ring selection — rotating K/V for the top-k gathered-block branch
# ---------------------------------------------------------------------------

def _selection_partial(qh, kc, vc, mc, loc, here, ell, scale_dim):
    """Partial stats of one selection hop.

    qh (B,Hkv,G,rep,g,D) head-major grouped queries; kc/vc (B,n,Hkv,D) the
    RESIDENT slab; mc (B,n) int32 token validity of the slab; loc
    (B,G,Hkv,k*) slab-local block indices with ``here`` marking selections
    resident on this slab.  Mirrors ``branches.gather_attend_blocks`` but
    returns unnormalised (m, l, acc) for the online merge."""
    B, n, Hkv, D = kc.shape
    nb = n // ell
    k_star = loc.shape[-1]
    G = loc.shape[1]
    L = k_star * ell
    safe = jnp.where(here, loc, 0)
    ig = safe.transpose(0, 2, 1, 3).reshape(B, Hkv, G * k_star)
    kb = kc.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    vb = vc.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    kg = jnp.take_along_axis(kb.reshape(B, Hkv, nb, ell * D),
                             ig[..., None], axis=2).reshape(B, Hkv, G, L, D)
    vg = jnp.take_along_axis(vb.reshape(B, Hkv, nb, ell * D),
                             ig[..., None], axis=2).reshape(B, Hkv, G, L, D)
    valid = jnp.broadcast_to(
        here.transpose(0, 2, 1, 3)[..., None], (B, Hkv, G, k_star, ell))
    tv = jnp.take_along_axis(mc.reshape(B, 1, nb, ell), ig[..., None],
                             axis=2) > 0
    valid = valid & tv.reshape(B, Hkv, G, k_star, ell)
    bias = mask_to_bias(valid.reshape(B, Hkv, G, 1, 1, L))
    logits = jnp.einsum("bhgrmd,bhgld->bhgrml", qh, kg,
                        preferred_element_type=jnp.float32) / (scale_dim ** 0.5)
    logits = logits + bias
    m = logits.max(-1)
    ph = jnp.exp(logits - m[..., None])
    ph = jnp.where(logits <= NEG_INF / 2, 0.0, ph)
    l = ph.sum(-1)
    acc = jnp.einsum("bhgrml,bhgld->bhgrmd", ph, vg.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return m, l, acc


def ring_selection(q, k, v, top_idx, sel_valid, key_valid, q_valid, *,
                   axis: str, p: int, block_size: int, group_size: int):
    """Sequence-sharded selection attention (call INSIDE shard_map).

    q (B, n, Hq, D) local queries; k/v (B, n, Hkv, D) the LOCAL K/V slab;
    top_idx/sel_valid (B, G_loc, Hkv, k*) this shard's groups with GLOBAL
    block indices; key_valid/q_valid (B, n) bool local validity.  Each hop
    re-bases the indices to the resident slab's coordinates and attends only
    the selections that live there; hops holding none are skipped at
    runtime.  Exact vs the replicated oracle because every global block is
    resident on exactly one shard (the hop partials partition each group's
    selected set).  Plain autodiff under an outer ``jax.checkpoint``: the
    backward replays the ring instead of saving per-hop gathers, so grads
    cost one extra revolution and O(n) memory."""
    from repro.kernels.occupancy import invalidate_dead_groups

    ell = block_size
    B, n, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    nb = n // ell
    G = top_idx.shape[1]
    g = group_size
    sel_valid = invalidate_dead_groups(sel_valid, q_valid, n)
    mc0 = (jnp.ones((B, n), jnp.int32) if key_valid is None
           else key_valid.astype(jnp.int32))

    def core(q, k, v, top_idx, sel_valid, mc):
        i = jax.lax.axis_index(axis)
        qh = q.reshape(B, G, g, Hkv, rep, D).transpose(0, 3, 1, 4, 2, 5)
        m = jnp.full((B, Hkv, G, rep, g), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, G, rep, g), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, rep, g, D), jnp.float32)
        kc, vc = k, v
        for h in range(p):
            src = (i - h) % p
            loc = top_idx - src * nb
            here = sel_valid & (loc >= 0) & (loc < nb)

            def hop(carry, kc=kc, vc=vc, mc=mc, loc=loc, here=here):
                mh, lh, ah = _selection_partial(qh, kc, vc, mc, loc, here,
                                                ell, D)
                return _merge(*carry, mh, lh, ah)

            # runtime dead-hop skip: the rotation below still runs on every
            # shard (collective), only the gather+matmuls are elided
            m, l, acc = jax.lax.cond(jnp.any(here), hop, lambda c: c,
                                     (m, l, acc))
            if h < p - 1:
                kc, vc, mc = _rotate((kc, vc, mc), axis, p)
        out = acc / jnp.maximum(l, _TINY)[..., None]
        out = out.transpose(0, 2, 4, 1, 3, 5).reshape(B, n, Hq, D)
        return out.astype(v.dtype)

    return jax.checkpoint(core)(q, k, v, top_idx, sel_valid, mc0)


# ---------------------------------------------------------------------------
# segment-sharded packed-varlen: LPT planner + axis re-layout
# ---------------------------------------------------------------------------

def lpt_partition(sizes, p: int) -> tuple:
    """Greedy LPT: samples in decreasing cost order (cost ∝ nᵢ², attention
    work is quadratic per sample) each go to the least-loaded shard.
    Returns the shard id per sample.  Classic 4/3-approximation of the
    optimal makespan — the skew test shows it beating round-robin by >1.5×
    on adversarial mixes."""
    sizes = np.asarray(sizes, np.int64)
    order = np.argsort(-(sizes.astype(np.float64) ** 2), kind="stable")
    loads = np.zeros(p, np.float64)
    assign = np.zeros(len(sizes), np.int64)
    for s in order:
        j = int(np.argmin(loads))
        assign[s] = j
        loads[j] += float(sizes[s]) ** 2
    return tuple(int(a) for a in assign)


def round_robin_partition(sizes, p: int) -> tuple:
    """Naive baseline: sample i → shard i mod p (what the skew test beats)."""
    return tuple(i % p for i in range(len(sizes)))


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """A sample→shard assignment for one packed-varlen batch.

    All fields are plain hashable tuples/ints so the plan itself keys the
    layout LRU.  ``cost_balance`` = max/mean per-shard Σnᵢ² (1.0 = perfect)."""

    p: int
    sizes: tuple            # (S,) token count per sample (trailing 0s = empty)
    assign: tuple           # (S,) shard id per sample

    @property
    def loads(self) -> tuple:
        out = [0] * self.p
        for sz, a in zip(self.sizes, self.assign):
            out[a] += sz
        return tuple(out)

    @property
    def cost_balance(self) -> float:
        cost = [0.0] * self.p
        for sz, a in zip(self.sizes, self.assign):
            cost[a] += float(sz) ** 2
        mean = sum(cost) / self.p
        return max(cost) / mean if mean else 1.0


@functools.lru_cache(maxsize=128)
def plan_segments(offsets: tuple, p: int,
                  partition=lpt_partition) -> SegmentPlan:
    """LPT-assign the samples of a CONCRETE offsets tuple to ``p`` shards."""
    sizes = tuple(int(b - a) for a, b in zip(offsets[:-1], offsets[1:]))
    return SegmentPlan(p=p, sizes=sizes, assign=partition(sizes, p))


@functools.lru_cache(maxsize=256)
def axis_layout(plan: SegmentPlan, offsets: tuple, total: int,
                pad_to: int = 1):
    """Per-shard contiguous re-layout of one packed axis.

    ``offsets`` are THIS axis's sample boundaries (the selection/ball token
    axis, or the compression branch's pooled block axis — any axis whose
    samples follow ``plan.assign``); ``total`` its global capacity.  Returns
    ``(idx, local_offsets, capacity, shift)``:

    * idx (p·capacity,) int32 — global position of each local slot, with the
      one-past-end index ``total`` marking padding slots (gathers pull a
      zero row, scatters land on a sliced-off row);
    * local_offsets (p, S+1) int32 — per-shard varlen offsets, trailing
      repeats for the samples a shard does not own (empty segments per the
      packed-varlen contract);
    * capacity int — per-shard padded length (max load rounded up to
      ``pad_to``, at least ``pad_to``);
    * shift (S,) int32 — local_start − global_start per sample (index
      re-basing for selection's global block coordinates).
    """
    starts = np.asarray(offsets[:-1], np.int64)
    ends = np.asarray(offsets[1:], np.int64)
    sizes = ends - starts
    loads = np.zeros(plan.p, np.int64)
    local_start = np.zeros(len(sizes), np.int64)
    for s, a in enumerate(plan.assign):
        local_start[s] = loads[a]
        loads[a] += sizes[s]
    capacity = max(int(loads.max()), 1)
    capacity = -(-capacity // pad_to) * pad_to
    idx = np.full((plan.p, capacity), total, np.int32)
    local_offsets = np.zeros((plan.p, len(offsets)), np.int32)
    for s, a in enumerate(plan.assign):
        idx[a, local_start[s]:local_start[s] + sizes[s]] = np.arange(
            starts[s], ends[s], dtype=np.int32)
        local_offsets[:, s + 1] = local_offsets[:, s]
        local_offsets[a, s + 1] = local_start[s] + sizes[s]
    shift = (local_start - starts).astype(np.int32)
    return idx.reshape(-1), local_offsets, capacity, shift


def split_tokens(idx, arr, p: int):
    """(T, …) global packed array → (p, capacity, …) per-shard slabs via a
    layout's gather index (padding slots read a zero row)."""
    pad = jnp.zeros((1,) + arr.shape[1:], arr.dtype)
    return jnp.concatenate([arr, pad], 0)[jnp.asarray(idx)].reshape(
        (p, -1) + arr.shape[1:])


def merge_tokens(idx, parts, total: int):
    """(p, capacity, …) per-shard outputs → (T, …) global packed array.
    Padding slots scatter onto the sliced-off row ``total``; global rows no
    sample owns (the capacity tail) come back exactly zero."""
    flat = parts.reshape((-1,) + parts.shape[2:])
    out = jnp.zeros((total + 1,) + flat.shape[1:], flat.dtype)
    return out.at[jnp.asarray(idx)].set(flat)[:total]


def lcm(a: int, b: int) -> int:
    return abs(a * b) // math.gcd(a, b) if a and b else max(a, b)
