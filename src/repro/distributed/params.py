"""Parameter / optimizer-state / batch / cache sharding inference.

Pattern rules (Megatron-style TP over the ``model`` axis):

  wq, wk, wv, gate, up (column-parallel)   → output dim over model
  wo, down (row-parallel)                  → input dim over model
  embed/tok_embed table                    → vocab dim over model
  lm_head                                  → vocab (output) dim over model
  MoE expert stacks w_gate/w_up/w_down     → EXPERT dim over model (EP)
  mamba in_proj/out_proj                   → inner dim over model
  everything else (norms, gates, biases)   → replicated

Stacked layer dims (leading ``n_periods`` axis) are never sharded.  Any rule
that does not divide evenly falls back to replication (the llava-56-heads
case).  Optimizer m/v additionally shard their largest remaining dim over the
DP axes — ZeRO-1 state partitioning.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (name, ndim-from-the-right dims to shard over `model`): index from the right
_COL = {"wq", "wk", "wv", "gate", "up", "lm_head", "fc1", "frontend_proj",
        "in_proj", "head"}
_ROW = {"wo", "down", "out_proj", "fc2"}
_VOCAB_TABLE = {"embed", "tok_embed"}
_EXPERT = {"w_gate", "w_up", "w_down"}


def _leaf_spec(path: tuple[str, ...], leaf) -> list[int]:
    """Priority-ordered candidate dims (index from the LEFT) to shard over
    ``model``; the first divisible one wins in ``_finalize``."""
    names = [p for p in path]
    if leaf.ndim == 0:
        return []
    field = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    if field == "w" or field == "b":
        field = parent
        parent = names[-3] if len(names) >= 3 else ""
    nd = leaf.ndim
    if field == "table" and parent in _VOCAB_TABLE:
        return [nd - 2]                 # (vocab, d_model) → shard vocab
    if field in _EXPERT:
        # EP when E | TP; else TP inside each expert (e.g. qwen's 60 experts
        # on a 16-way axis): column dim for w_gate/w_up, row dim for w_down
        inner = nd - 1 if field in ("w_gate", "w_up") else nd - 2
        return [nd - 3, inner]
    if field in _COL and nd >= 2:
        return [nd - 1]                 # output dim
    if field in _ROW and nd >= 2:
        return [nd - 2]                 # input dim
    return []


def _finalize(cands, shape, mesh: Mesh, *, zero1: bool = False,
              all_axes: bool = False) -> P:
    axes_model = mesh.shape.get("model", 1)
    dp_names = ("pod", "data", "model") if all_axes else ("pod", "data")
    dp_axes = tuple(a for a in dp_names if a in mesh.shape)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    out: list = [None] * len(shape)
    for i in cands:
        if axes_model > 1 and shape[i] % axes_model == 0:
            out[i] = "model"
            break
    if zero1 and dp_axes and dp_size > 1:
        # ZeRO-1: shard the largest still-unsharded dim over DP if divisible
        cand = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in cand:
            if out[i] is None and shape[i] % dp_size == 0 and shape[i] >= dp_size:
                out[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
    return P(*out)


def param_shardings(params_struct, mesh: Mesh, *, zero1: bool = False,
                    tp: bool = True):
    """Pytree of NamedSharding matching ``params_struct``.  ``tp=False``
    disables model-axis tensor parallelism (DP-heavy layout for small
    models); params then rely on zero1/FSDP over ALL mesh axes."""
    def one(path, leaf):
        names = tuple(_key_name(k) for k in path)
        spec = _leaf_spec(names, leaf) if tp else []
        return NamedSharding(mesh, _finalize(spec, leaf.shape, mesh,
                                             zero1=zero1, all_axes=not tp))
    return jax.tree_util.tree_map_with_path(one, params_struct)


def opt_shardings(opt_struct, mesh: Mesh, *, tp: bool = True):
    """m/v follow param rules + ZeRO-1 over DP; step replicated."""
    def one(path, leaf):
        names = tuple(_key_name(k) for k in path)
        if names and names[0] == "step":
            return NamedSharding(mesh, P())
        names = names[1:] if names and names[0] in ("m", "v") else names
        spec = _leaf_spec(names, leaf) if tp else []
        return NamedSharding(mesh, _finalize(spec, leaf.shape, mesh, zero1=True,
                                             all_axes=not tp))
    return jax.tree_util.tree_map_with_path(one, opt_struct)


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def batch_shardings(batch_struct, mesh: Mesh, *, seq_parallel: bool = False,
                    full_dp: bool = False):
    """Batch dim over DP axes; in SP mode (global_batch < DP) shard the
    SEQUENCE dim over `data` instead (BSA makes this collective-cheap).
    ``full_dp`` spreads batch over the model axis too (DP-heavy layout)."""
    names = ("pod", "data", "model") if full_dp else ("pod", "data")
    dp = tuple(a for a in names if a in mesh.shape)

    def one(leaf):
        spec: list = [None] * leaf.ndim
        if leaf.ndim >= 1:
            dp_size = int(np.prod([mesh.shape[a] for a in dp]))
            if not seq_parallel and dp and leaf.shape[0] % dp_size == 0:
                spec[0] = dp if len(dp) > 1 else dp[0]
            elif seq_parallel and leaf.ndim >= 2 and "data" in mesh.shape \
                    and leaf.shape[1] % mesh.shape["data"] == 0:
                spec[1] = "data"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, batch_struct)


def cache_shardings(cache_struct, mesh: Mesh, *, seq_parallel: bool = False):
    """KV caches: batch over DP; kv-head dim over model when divisible; in SP
    mode the cache SEQUENCE dim shards over `data`."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model = mesh.shape.get("model", 1)

    def one(path, leaf):
        names = tuple(_key_name(k) for k in path)
        spec: list = [None] * leaf.ndim
        # layout conventions (leading stacked period dim is axis 0 when ndim>?):
        # k/v:      (NP, B, S, Hkv, D)   k_cmp/v_cmp: (NP, B, NB, Hkv, D)
        # mamba h:  (NP, B, H, Ns, P)    conv: (NP, B, W, C)   length: (NP,)
        # encdec adds mem_k/mem_v: (NP, B, S, Hkv, D)
        field = names[-1] if names else ""
        if field == "length" or leaf.ndim <= 1:
            return NamedSharding(mesh, P(*spec))
        b_axis = 1 if leaf.ndim >= 3 else 0
        if not seq_parallel and dp and leaf.shape[b_axis] % dp_size == 0:
            spec[b_axis] = dp if len(dp) > 1 else dp[0]
        if field in ("k", "v", "k_cmp", "v_cmp", "mem_k", "mem_v") and leaf.ndim >= 5:
            if seq_parallel and "data" in mesh.shape \
                    and leaf.shape[2] % mesh.shape["data"] == 0:
                spec[2] = "data"
            if leaf.shape[3] % model == 0 and model > 1:
                spec[3] = "model"
            elif spec[2] is None and model > 1 and leaf.shape[2] % model == 0:
                # kv_heads ∤ model (e.g. 8 heads on a 16-way axis): shard the
                # cache SEQUENCE over model instead — BSA decode touches the
                # cache blockwise, so this stays collective-cheap
                spec[2] = "model"
        elif field == "h" and leaf.ndim >= 5 and leaf.shape[2] % model == 0:
            spec[2] = "model"           # mamba state heads
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_struct)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
