"""Multi-device attention backend: ``shard_map``ped BSA over a device mesh.

The ``"sharded"`` backend wraps any inner single-device backend
(``"jnp"`` / ``"pallas"`` / ``"interpret"``) and runs the four GQA-native
ops of the backend protocol under :func:`jax.experimental.shard_map` on the
mesh activated by :func:`mesh_context` — the distributed analogue of
``use_backend()``:

    with mesh_context(make_local_mesh()), use_backend("sharded"):
        out = bsa_attention(params, q, k, v, cfg=cfg)   # no call-site change

Per-branch sharding strategy (see docs/distributed.md for the full table):

* ``ball`` — ball-axis DATA parallelism.  Balls are independent attention
  units, so the sequence dim is sharded in ball-multiple chunks and the
  inner backend runs unmodified per shard.  **No collectives.**
* ``local_window`` — sequence sharded in window-multiple chunks plus a
  one-block **halo exchange** (``lax.ppermute``): each shard receives its
  left neighbour's last block of K/V so block 0 of the shard can attend its
  previous block.  Shard 0's halo arrives zero-filled with an all-False
  mask, which reproduces the reference's first-block rule exactly.
* ``flash`` (compression branch) — CONTEXT parallelism: queries sharded,
  the T/ℓ-small compressed K/V replicated (the implicit all-gather is
  cheap by construction).  Softmax is psum-free — each query sees its full
  key set locally.  The block-causal rule is position-dependent, so the
  sharded path computes it from the reference math with a per-shard
  ``pos0`` offset (``axis_index * n_local``) rather than the inner kernel,
  whose grid parameters must be trace-static.
* ``selection`` — queries, selected indices and validity sharded along the
  group axis; K/V and the key mask replicated.  Requires an inner backend
  whose ``selection`` accepts the ``q_valid`` kwarg (both built-ins do):
  the key-sized mask can no longer double as the query mask when N < L.

Gradients: ``shard_map``'s transpose rule psums cotangents of replicated
inputs, so gathered-K/V grads are automatically reduce-scattered back to
their owner shards — the fused ``custom_vjp`` backwards of the inner
backend stay shard-correct with no extra code.

Whenever an op cannot shard (indivisible sizes, missing ``q_valid``
support, 1-device mesh) it falls back to the inner backend unsharded and
warns ONCE per cause — numerics never change, only the partitioning.

The module also provides :func:`sharded_paged_decode`: the paged NSA decode
step with the KV pools row-partitioned across the mesh axis
(``core.nsa_causal`` dispatches here when the resolved backend is sharded).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import warnings

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.backend import (
    accepts_kwarg,
    get_backend,
    list_backends,
    register_backend,
)
from repro.distributed.sharding import axis_rules, logical_to_spec

__all__ = [
    "ShardedBackend",
    "mesh_context",
    "current_mesh_axis",
    "sharded_paged_decode",
]


# ---------------------------------------------------------------------------
# mesh_context — the distributed analogue of use_backend()
# ---------------------------------------------------------------------------

_TLS = threading.local()
_WARNED: set = set()


def _warn_once(op: str, reason: str) -> None:
    key = (op, reason)
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(f"sharded backend: {op} falls back to the inner "
                      f"backend unsharded — {reason}", RuntimeWarning,
                      stacklevel=3)


@contextlib.contextmanager
def mesh_context(mesh, *, axis: str = "data", rules: dict | None = None):
    """Activate ``mesh`` for the ``"sharded"`` backend (trace-time scoped).

    ``axis`` names the mesh axis the sequence/ball dim is sharded over.
    Also enters :func:`repro.distributed.sharding.axis_rules` so ``constrain``
    annotations in ``core`` resolve against the same mesh: the merged rules
    point ``seq_sp`` at ``axis`` and stop ``batch`` from grabbing it first
    (override via ``rules`` for batch-parallel setups).
    """
    if axis not in mesh.shape:
        raise ValueError(f"mesh_context: axis {axis!r} not in mesh axes "
                         f"{tuple(mesh.shape)}")
    merged = {"batch": None, "seq_sp": (axis,)}
    if rules:
        merged.update(rules)
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    stack.append((mesh, axis))
    try:
        with axis_rules(mesh, merged):
            yield mesh
    finally:
        stack.pop()


def current_mesh_axis():
    """(mesh, axis) of the innermost active :func:`mesh_context`, or None."""
    stack = getattr(_TLS, "stack", None)
    return stack[-1] if stack else None


# ---------------------------------------------------------------------------
# shard_map plumbing helpers
# ---------------------------------------------------------------------------

def _shard_call(mesh, body, arg_specs, out_specs):
    """shard_map with None-arg skipping.

    ``arg_specs``: list of (array-or-None, PartitionSpec).  None entries are
    closed over (shard_map cannot spec them) and re-inserted so ``body``
    always receives the full positional list.
    """
    args = [a for a, _ in arg_specs if a is not None]
    specs = tuple(s for a, s in arg_specs if a is not None)
    present = [a is not None for a, _ in arg_specs]

    def wrapper(*xs):
        it = iter(xs)
        return body(*[next(it) if pr else None for pr in present])

    return shard_map(wrapper, mesh=mesh, in_specs=specs,
                     out_specs=out_specs, check_rep=False)(*args)


# ---------------------------------------------------------------------------
# The backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedBackend:
    """shard_map wrapper around an inner backend (see module docstring).

    ``inner`` pins the wrapped backend by name; None defers to the
    ``REPRO_SHARDED_INNER`` env var, then ``"auto"`` (pallas on TPU, jnp
    elsewhere).  The mesh is NOT stored here — it is resolved at trace time
    from the ambient :func:`mesh_context`, exactly like ``use_backend``
    resolves the backend name.
    """

    name: str = "sharded"
    inner: str | None = None
    requires_mesh = True         # engines fail fast without a mesh_context
    is_sharded_backend = True    # decode dispatch marker (core.nsa_causal)

    # -- resolution ---------------------------------------------------------

    def _resolve_inner(self):
        name = os.environ.get("REPRO_SHARDED_INNER") or self.inner or "auto"
        if name == "sharded":
            raise ValueError("the sharded backend cannot wrap itself "
                             "(REPRO_SHARDED_INNER/inner must name a "
                             "single-device backend)")
        return get_backend(name)

    def _require_mesh(self, op: str):
        ctx = current_mesh_axis()
        if ctx is None:
            raise RuntimeError(
                f"the 'sharded' backend needs an active mesh to run {op!r}; "
                "wrap the call (or trace) in\n"
                "    from repro.distributed import mesh_context\n"
                "    from repro.launch.mesh import make_local_mesh\n"
                "    with mesh_context(make_local_mesh()):\n"
                "        ...\n"
                "(on CPU, XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "fakes a mesh for smoke runs)")
        return ctx

    def _plan(self, op: str, mesh, axis, n: int, granule: int = 1):
        """Shard count p along ``axis`` if dim ``n`` can shard, else None.

        Routes through ``logical_to_spec`` so divisibility failures surface
        through its one-shot warning, then applies the op's granule rule
        (per-shard length must stay a multiple of the ball/window size).
        """
        p = mesh.shape[axis]
        if p == 1:
            return None
        spec = logical_to_spec(("seq_shard",), (n,), mesh,
                               {"seq_shard": (axis,)})
        if spec[0] is None:
            _warn_once(op, f"dim {n} not divisible by mesh axis "
                           f"{axis!r}={p}")
            return None
        if (n // p) % granule:
            _warn_once(op, f"per-shard length {n // p} not a multiple of "
                           f"granule {granule} (dim {n}, {axis!r}={p})")
            return None
        return p

    # -- ops ----------------------------------------------------------------

    def ball(self, q, k, v, mask, *, ball_size, chunk_tokens=0):
        mesh, axis = self._require_mesh("ball")
        inner = self._resolve_inner()
        if self._plan("ball", mesh, axis, q.shape[1], ball_size) is None:
            return inner.ball(q, k, v, mask, ball_size=ball_size,
                              chunk_tokens=chunk_tokens)
        seq = P(None, axis)

        def body(q, k, v, m):
            return inner.ball(q, k, v, m, ball_size=ball_size,
                              chunk_tokens=chunk_tokens)

        return _shard_call(mesh, body,
                           [(q, seq), (k, seq), (v, seq), (mask, seq)], seq)

    def local_window(self, q, k, v, *, window, mask=None, chunk_tokens=0):
        mesh, axis = self._require_mesh("local_window")
        inner = self._resolve_inner()
        p = self._plan("local_window", mesh, axis, q.shape[1], window)
        if p is None:
            return inner.local_window(q, k, v, window=window, mask=mask,
                                      chunk_tokens=chunk_tokens)
        if mask is None:
            mask = jnp.ones(q.shape[:2], bool)   # ones ≡ None numerically
        seq = P(None, axis)
        w = window
        perm = [(i, i + 1) for i in range(p - 1)]   # shard 0 gets zero-fill

        def body(q, k, v, m):
            # halo exchange: left neighbour's last block, so this shard's
            # block 0 can attend its previous block; the zero query block
            # keeps the inner's blocked layout aligned and is sliced off
            hk = jax.lax.ppermute(k[:, -w:], axis, perm)
            hv = jax.lax.ppermute(v[:, -w:], axis, perm)
            hm = jax.lax.ppermute(m[:, -w:].astype(jnp.int32), axis, perm) > 0
            out = inner.local_window(
                jnp.concatenate([jnp.zeros_like(q[:, :w]), q], axis=1),
                jnp.concatenate([hk, k], axis=1),
                jnp.concatenate([hv, v], axis=1),
                window=w,
                mask=jnp.concatenate([hm, m], axis=1),
                chunk_tokens=chunk_tokens)
            return out[:, w:]

        return _shard_call(mesh, body,
                           [(q, seq), (k, seq), (v, seq), (mask, seq)], seq)

    def flash(self, q, k, v, *, key_valid=None, causal=False,
              block_causal=False, ell=1, chunk_tokens=0, q_valid=None):
        mesh, axis = self._require_mesh("flash")
        inner = self._resolve_inner()
        inner_kw = {}
        if q_valid is not None and accepts_kwarg(inner.flash, "q_valid"):
            inner_kw["q_valid"] = q_valid
        if causal:
            # token-causal flash is only the dense-baseline path; its
            # position rule is not offset-parameterised in the inners
            _warn_once("flash", "token-level causal not context-parallel")
            return inner.flash(q, k, v, key_valid=key_valid, causal=True,
                               block_causal=block_causal, ell=ell,
                               chunk_tokens=chunk_tokens, **inner_kw)
        N = q.shape[1]
        p = self._plan("flash", mesh, axis, N)
        if p is None:
            return inner.flash(q, k, v, key_valid=key_valid,
                               block_causal=block_causal, ell=ell,
                               chunk_tokens=chunk_tokens, **inner_kw)
        seq = P(None, axis)
        n_loc = N // p

        if block_causal:
            # the block-causal rule depends on GLOBAL query position; the
            # shard offset is traced (axis_index), which a kernel grid
            # cannot take — so the sharded path computes the branch with
            # the reference math + pos0 (exact parity with inner="jnp")
            from repro.core.branches import chunked_q_attention, repeat_kv

            def body(q, k, v, kv):
                pos0 = jax.lax.axis_index(axis) * n_loc
                rep = q.shape[2] // k.shape[2]
                return chunked_q_attention(
                    q, repeat_kv(k, rep), repeat_kv(v, rep), key_valid=kv,
                    block_causal_ell=ell, chunk=chunk_tokens, pos0=pos0)
        else:
            def body(q, k, v, kv):
                kw = dict(inner_kw)
                if "q_valid" in kw:
                    kw["q_valid"] = None   # global hint, wrong per shard
                return inner.flash(q, k, v, key_valid=kv, ell=ell,
                                   chunk_tokens=chunk_tokens, **kw)

        return _shard_call(mesh, body,
                           [(q, seq), (k, P()), (v, P()),
                            (key_valid, P())], seq)

    def selection(self, q, k, v, top_idx, sel_valid, mask, *, block_size,
                  group_size, chunk_tokens=0, q_valid=None):
        mesh, axis = self._require_mesh("selection")
        inner = self._resolve_inner()
        N, G = q.shape[1], top_idx.shape[1]
        p = self._plan("selection", mesh, axis, N)
        if p is not None and G % p:
            _warn_once("selection", f"G={G} not divisible by {axis!r}={p}")
            p = None
        if p is not None and not accepts_kwarg(inner.selection, "q_valid"):
            _warn_once("selection", f"inner backend {inner.name!r} has no "
                       "q_valid support (needed to split query/key masks)")
            p = None
        if p is None:
            return inner.selection(q, k, v, top_idx, sel_valid, mask,
                                   block_size=block_size,
                                   group_size=group_size,
                                   chunk_tokens=chunk_tokens)
        seq = P(None, axis)

        def body(q, ti, sv, k, v, m, qv):
            return inner.selection(q, k, v, ti, sv, m,
                                   block_size=block_size,
                                   group_size=group_size,
                                   chunk_tokens=chunk_tokens, q_valid=qv)

        return _shard_call(
            mesh, body,
            [(q, seq), (top_idx, seq), (sel_valid, seq),
             (k, P()), (v, P()),
             (mask, P()),          # key-token validity: replicated, full L
             (mask, seq)],         # query validity: this shard's slice
            seq)


# ---------------------------------------------------------------------------
# Sequence-sharded paged decode (ServingEngine integration)
# ---------------------------------------------------------------------------

class _ShardedPoolOps:
    """Row-partitioned pool access for the paged decode.

    Pools are split along dim 0 into contiguous row blocks, one per shard.
    Gathers read OOB-safe locally (``mode="fill"`` zeros for rows another
    shard owns) and psum — exact, since every row has one nonzero
    contributor.  Scatters drop non-owned rows (``mode="drop"``), so each
    row is written only by its owner and no collective is needed.
    """

    def __init__(self, axis: str):
        self.axis = axis

    def _local(self, pool, rows):
        # rows this shard does not own map to r_loc — PAST the local end, so
        # fill/drop modes treat them as OOB.  (A bare negative index would
        # WRAP per Python indexing semantics before the OOB check.)
        r_loc = pool.shape[0]
        li = rows - jax.lax.axis_index(self.axis) * r_loc
        return jnp.where((li >= 0) & (li < r_loc), li, r_loc)

    def gather(self, pool, rows):
        g = pool.at[self._local(pool, rows)].get(mode="fill", fill_value=0)
        return jax.lax.psum(g, self.axis)

    def gather_head(self, pool, rows, head_idx):
        hb = jnp.broadcast_to(head_idx, rows.shape)
        g = pool.at[self._local(pool, rows), hb].get(mode="fill",
                                                     fill_value=0)
        return jax.lax.psum(g, self.axis)

    def scatter_rows(self, pool, rows, vals):
        return pool.at[self._local(pool, rows)].set(vals.astype(pool.dtype),
                                                    mode="drop")


def sharded_paged_decode(backend, params, q1, k1, v1, cache, table,
                         lengths, *, cfg, page, x1=None):
    """One paged NSA decode step with KV pools partitioned across the mesh.

    Called from ``core.nsa_causal.nsa_causal_decode_paged`` when the
    resolved backend is sharded.  The whole step runs under one
    ``shard_map``: pools enter/leave row-sharded (``P(axis)``), everything
    else (query, table, lengths, params) is replicated, and the attention
    output is identical on every shard (gathers psum).  Requires the pool
    row counts R and Rc to divide the mesh axis; otherwise falls back to
    the dense single-device pool ops under the inner backend.
    """
    from repro.core import nsa_causal
    from repro.core.backend import get_paged_gather

    mesh, axis = backend._require_mesh("paged decode")
    inner = backend._resolve_inner()
    p = mesh.shape[axis]
    R, Rc = cache["k"].shape[0], cache["k_cmp"].shape[0]
    if p == 1 or R % p or Rc % p:
        if p > 1:
            _warn_once("paged decode", f"pool rows R={R}/Rc={Rc} not "
                       f"divisible by {axis!r}={p}")
        ops = nsa_causal._DensePoolOps(get_paged_gather(inner))
        return nsa_causal.nsa_causal_decode_paged(
            params, q1, k1, v1, cache, table, lengths, cfg=cfg, page=page,
            x1=x1, _pool_ops=ops)

    pool_ops = _ShardedPoolOps(axis)
    pool_spec = {name: P(axis) for name in cache}

    def body(params, q1, k1, v1, cache, table, lengths, x1):
        return nsa_causal.nsa_causal_decode_paged(
            params, q1, k1, v1, cache, table, lengths, cfg=cfg, page=page,
            x1=x1, _pool_ops=pool_ops)

    args = [(params, P()), (q1, P()), (k1, P()), (v1, P()),
            (cache, pool_spec), (table, P()), (lengths, P()), (x1, P())]
    arrs = [a for a, _ in args if a is not None]
    specs = tuple(s for a, s in args if a is not None)
    present = [a is not None for a, _ in args]

    def wrapper(*xs):
        it = iter(xs)
        return body(*[next(it) if pr else None for pr in present])

    return shard_map(wrapper, mesh=mesh, in_specs=specs,
                     out_specs=(P(), pool_spec), check_rep=False)(*arrs)


if "sharded" not in list_backends():       # idempotent on re-import paths
    register_backend("sharded", ShardedBackend())
