"""Multi-device attention backend: ``shard_map``ped BSA over a device mesh.

The ``"sharded"`` backend wraps any inner single-device backend
(``"jnp"`` / ``"pallas"`` / ``"interpret"``) and runs the four GQA-native
ops of the backend protocol under :func:`jax.experimental.shard_map` on the
mesh activated by :func:`mesh_context` — the distributed analogue of
``use_backend()``:

    with mesh_context(make_local_mesh()), use_backend("sharded"):
        out = bsa_attention(params, q, k, v, cfg=cfg)   # no call-site change

Per-branch sharding strategy (see docs/distributed.md for the full table):

* ``ball`` — ball-axis DATA parallelism.  Balls are independent attention
  units, so the sequence dim is sharded in ball-multiple chunks and the
  inner backend runs unmodified per shard.  **No collectives.**
* ``local_window`` — sequence sharded in window-multiple chunks plus a
  one-block **halo exchange** (``lax.ppermute``): each shard receives its
  left neighbour's last block of K/V so block 0 of the shard can attend its
  previous block.  Shard 0's halo arrives zero-filled with an all-False
  mask, which reproduces the reference's first-block rule exactly.
* ``flash`` — CONTEXT parallelism.  Non-causal / block-causal: queries
  sharded, the T/ℓ-small compressed K/V replicated (the implicit all-gather
  is cheap by construction).  TOKEN-CAUSAL flash runs the
  :func:`repro.distributed.ring.ring_flash` primitive instead: q, K and V
  all sequence-sharded, K/V slabs rotating via ``lax.ppermute`` with
  online-softmax merging, and the static hop-live table
  (``occupancy.ring_hop_live``) skipping the ~half of the hops the causal
  mask kills.  Per-shard K/V memory O(L/p), p−1 hops of (B·L/p·Hkv·D)
  bytes each.
* ``selection`` — queries, selected indices and validity sharded along the
  group axis AND K/V + key mask sequence-sharded:
  :func:`repro.distributed.ring.ring_selection` rotates the K/V slabs,
  re-bases the global top-k block indices to each resident slab's
  coordinates, attends only the selections that live there, and skips hops
  that hold none at runtime.  Nothing is replicated any more.
* packed-varlen (``*_varlen``) — SEGMENT sharding.  A greedy LPT partition
  (cost ∝ nᵢ², :func:`repro.distributed.ring.plan_segments`) assigns
  samples to shards, the packed axis is re-laid out as one contiguous
  padded slab per shard, and the inner backend's varlen ops run per shard
  on plain LOCAL offsets — samples never attend each other, so ball, local,
  selection (indices re-based by the per-sample shift) and the compression
  flash (its pooled block axis laid out with the SAME assignment, i.e. the
  ring's hop-0 term) all run with ZERO collectives.  Needs CONCRETE
  offsets: traced offsets (jit without static boundaries) fall back with a
  warning.

Gradients: ``shard_map``'s transpose rule psums cotangents of replicated
inputs and transposes ``ppermute`` to the reverse rotation, so all paths —
including the hand-written ring-flash ``custom_vjp`` and the re-layout
gathers — stay shard-correct with no extra code.

Whenever an op cannot shard (indivisible sizes, traced offsets, 1-device
mesh) it falls back to the inner backend unsharded and warns ONCE per
(op, cause) — numerics never change, only the partitioning.

The module also provides :func:`sharded_paged_decode`: the paged NSA decode
step with the KV pools row-partitioned across the mesh axis
(``core.nsa_causal`` dispatches here when the resolved backend is sharded).
Its compression branch reuses the ring's statistics merge: each shard
attends its OWN compressed rows and only the (m, l, acc) triples are
psum-merged — an O(B·Hq·D) collective instead of all-gathering the
O(B·NB·Hkv·D) compressed K/V (set ``REPRO_SHARDED_RING_DECODE=0`` to
restore the gather+psum path).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import warnings

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.backend import (
    get_backend,
    get_varlen,
    list_backends,
    register_backend,
)
from repro.distributed import ring
from repro.distributed.sharding import axis_rules, logical_to_spec

__all__ = [
    "ShardedBackend",
    "mesh_context",
    "current_mesh_axis",
    "sharded_paged_decode",
    "reset_warnings",
]


# ---------------------------------------------------------------------------
# mesh_context — the distributed analogue of use_backend()
# ---------------------------------------------------------------------------

_TLS = threading.local()
_WARNED: set = set()


def _warn_once(op: str, code: str, detail: str) -> None:
    """Warn once per (op, cause).  ``code`` is a STABLE cause identifier —
    ``detail`` may embed dynamic shapes, so keying on it (or on the op
    alone) would either re-warn per shape or let one cause suppress a
    different one for the same op."""
    key = (op, code)
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(f"sharded backend: {op} falls back to the inner "
                      f"backend unsharded [{code}] — {detail}",
                      RuntimeWarning, stacklevel=3)


def reset_warnings() -> None:
    """Clear the warn-once registry (test isolation)."""
    _WARNED.clear()


@contextlib.contextmanager
def mesh_context(mesh, *, axis: str = "data", rules: dict | None = None):
    """Activate ``mesh`` for the ``"sharded"`` backend (trace-time scoped).

    ``axis`` names the mesh axis the sequence/ball dim is sharded over.
    Also enters :func:`repro.distributed.sharding.axis_rules` so ``constrain``
    annotations in ``core`` resolve against the same mesh: the merged rules
    point ``seq_sp`` at ``axis`` and stop ``batch`` from grabbing it first
    (override via ``rules`` for batch-parallel setups).
    """
    if axis not in mesh.shape:
        raise ValueError(f"mesh_context: axis {axis!r} not in mesh axes "
                         f"{tuple(mesh.shape)}")
    merged = {"batch": None, "seq_sp": (axis,)}
    if rules:
        merged.update(rules)
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    stack.append((mesh, axis))
    try:
        with axis_rules(mesh, merged):
            yield mesh
    finally:
        stack.pop()


def current_mesh_axis():
    """(mesh, axis) of the innermost active :func:`mesh_context`, or None."""
    stack = getattr(_TLS, "stack", None)
    return stack[-1] if stack else None


# ---------------------------------------------------------------------------
# shard_map plumbing helpers
# ---------------------------------------------------------------------------

def _shard_call(mesh, body, arg_specs, out_specs):
    """shard_map with None-arg skipping.

    ``arg_specs``: list of (array-or-None, PartitionSpec).  None entries are
    closed over (shard_map cannot spec them) and re-inserted so ``body``
    always receives the full positional list.
    """
    args = [a for a, _ in arg_specs if a is not None]
    specs = tuple(s for a, s in arg_specs if a is not None)
    present = [a is not None for a, _ in arg_specs]

    def wrapper(*xs):
        it = iter(xs)
        return body(*[next(it) if pr else None for pr in present])

    return shard_map(wrapper, mesh=mesh, in_specs=specs,
                     out_specs=out_specs, check_rep=False)(*args)


# ---------------------------------------------------------------------------
# The backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedBackend:
    """shard_map wrapper around an inner backend (see module docstring).

    ``inner`` pins the wrapped backend by name; None defers to the
    ``REPRO_SHARDED_INNER`` env var, then ``"auto"`` (pallas on TPU, jnp
    elsewhere).  The mesh is NOT stored here — it is resolved at trace time
    from the ambient :func:`mesh_context`, exactly like ``use_backend``
    resolves the backend name.
    """

    name: str = "sharded"
    inner: str | None = None
    requires_mesh = True         # engines fail fast without a mesh_context
    is_sharded_backend = True    # decode dispatch marker (core.nsa_causal)

    # -- resolution ---------------------------------------------------------

    def _resolve_inner(self):
        name = os.environ.get("REPRO_SHARDED_INNER") or self.inner or "auto"
        if name == "sharded":
            raise ValueError("the sharded backend cannot wrap itself "
                             "(REPRO_SHARDED_INNER/inner must name a "
                             "single-device backend)")
        return get_backend(name)

    def _require_mesh(self, op: str):
        ctx = current_mesh_axis()
        if ctx is None:
            raise RuntimeError(
                f"the 'sharded' backend needs an active mesh to run {op!r}; "
                "wrap the call (or trace) in\n"
                "    from repro.distributed import mesh_context\n"
                "    from repro.launch.mesh import make_local_mesh\n"
                "    with mesh_context(make_local_mesh()):\n"
                "        ...\n"
                "(on CPU, XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "fakes a mesh for smoke runs)")
        return ctx

    def _plan(self, op: str, mesh, axis, n: int, granule: int = 1):
        """Shard count p along ``axis`` if dim ``n`` can shard, else None.

        Routes through ``logical_to_spec`` so divisibility failures surface
        through its one-shot warning, then applies the op's granule rule
        (per-shard length must stay a multiple of the ball/window size).
        """
        p = mesh.shape[axis]
        if p == 1:
            return None
        spec = logical_to_spec(("seq_shard",), (n,), mesh,
                               {"seq_shard": (axis,)})
        if spec[0] is None:
            _warn_once(op, "indivisible-dim",
                       f"dim {n} not divisible by mesh axis {axis!r}={p}")
            return None
        if (n // p) % granule:
            _warn_once(op, "granule",
                       f"per-shard length {n // p} not a multiple of "
                       f"granule {granule} (dim {n}, {axis!r}={p})")
            return None
        return p

    def _segment_plan(self, op: str, mesh, axis, offsets, granules=()):
        """LPT sample→shard plan for a packed-varlen op, or None → fallback.

        Needs CONCRETE offsets (the partition is a host-side decision) and
        every sample size divisible by each granule (so the re-laid-out
        local starts keep block/group boundaries aligned)."""
        from repro.kernels.occupancy import offsets_digest
        p = mesh.shape[axis]
        if p == 1:
            return None
        dig = offsets_digest(offsets)
        if dig is None:
            _warn_once(op, "traced-offsets",
                       "offsets are traced (jitted without concrete "
                       "boundaries); the LPT segment partition is a "
                       "host-side decision")
            return None
        sizes = [b - a for a, b in zip(dig[:-1], dig[1:])]
        for gr in granules:
            if gr > 1 and any(sz % gr for sz in sizes):
                _warn_once(op, "granule",
                           f"sample sizes not all multiples of granule {gr}")
                return None
        return p, ring.plan_segments(dig, p), dig

    # -- dense ops ----------------------------------------------------------

    def ball(self, q, k, v, mask, *, ball_size, chunk_tokens=0):
        mesh, axis = self._require_mesh("ball")
        inner = self._resolve_inner()
        if self._plan("ball", mesh, axis, q.shape[1], ball_size) is None:
            return inner.ball(q, k, v, mask, ball_size=ball_size,
                              chunk_tokens=chunk_tokens)
        seq = P(None, axis)

        def body(q, k, v, m):
            return inner.ball(q, k, v, m, ball_size=ball_size,
                              chunk_tokens=chunk_tokens)

        return _shard_call(mesh, body,
                           [(q, seq), (k, seq), (v, seq), (mask, seq)], seq)

    def local_window(self, q, k, v, *, window, mask=None, chunk_tokens=0):
        mesh, axis = self._require_mesh("local_window")
        inner = self._resolve_inner()
        p = self._plan("local_window", mesh, axis, q.shape[1], window)
        if p is None:
            return inner.local_window(q, k, v, window=window, mask=mask,
                                      chunk_tokens=chunk_tokens)
        if mask is None:
            mask = jnp.ones(q.shape[:2], bool)   # ones ≡ None numerically
        seq = P(None, axis)
        w = window
        perm = [(i, i + 1) for i in range(p - 1)]   # shard 0 gets zero-fill

        def body(q, k, v, m):
            # halo exchange: left neighbour's last block, so this shard's
            # block 0 can attend its previous block; the zero query block
            # keeps the inner's blocked layout aligned and is sliced off
            hk = jax.lax.ppermute(k[:, -w:], axis, perm)
            hv = jax.lax.ppermute(v[:, -w:], axis, perm)
            hm = jax.lax.ppermute(m[:, -w:].astype(jnp.int32), axis, perm) > 0
            out = inner.local_window(
                jnp.concatenate([jnp.zeros_like(q[:, :w]), q], axis=1),
                jnp.concatenate([hk, k], axis=1),
                jnp.concatenate([hv, v], axis=1),
                window=w,
                mask=jnp.concatenate([hm, m], axis=1),
                chunk_tokens=chunk_tokens)
            return out[:, w:]

        return _shard_call(mesh, body,
                           [(q, seq), (k, seq), (v, seq), (mask, seq)], seq)

    def flash(self, q, k, v, *, key_valid=None, causal=False,
              block_causal=False, ell=1, chunk_tokens=0, q_valid=None):
        from repro.core.backend import accepts_kwarg

        mesh, axis = self._require_mesh("flash")
        inner = self._resolve_inner()
        inner_kw = {}
        if q_valid is not None and accepts_kwarg(inner.flash, "q_valid"):
            inner_kw["q_valid"] = q_valid
        N, L = q.shape[1], k.shape[1]
        if causal:
            # ring flash: q, K and V all sequence-sharded, K/V rotating —
            # the token-causal rule needs aligned q/k axes to place global
            # positions, which holds whenever N == L (the dense-baseline
            # layout; decode's right-aligned N < L stays unsharded)
            p = self._plan("flash", mesh, axis, N) if N == L else None
            if N != L:
                _warn_once("flash", "causal-qk-mismatch",
                           f"token-causal q len {N} != k len {L} "
                           "(right-aligned decode layout) cannot ring-shard")
            if p is None:
                return inner.flash(q, k, v, key_valid=key_valid, causal=True,
                                   block_causal=block_causal, ell=ell,
                                   chunk_tokens=chunk_tokens, **inner_kw)
            from repro.kernels import occupancy
            from repro.numerics import key_padding_bias

            live = occupancy.ring_hop_live(p, N // p, causal=True)
            occupancy.record("ring_flash", live)
            kb = key_padding_bias(key_valid, q.shape[0], L)
            seq = P(None, axis)

            def body(q, k, v, kb):
                return ring.ring_flash(q, k, v, kb, axis=axis, p=p,
                                       causal=True, live=live)

            return _shard_call(mesh, body,
                               [(q, seq), (k, seq), (v, seq), (kb, seq)],
                               seq)
        p = self._plan("flash", mesh, axis, N)
        if p is None:
            return inner.flash(q, k, v, key_valid=key_valid,
                               block_causal=block_causal, ell=ell,
                               chunk_tokens=chunk_tokens, **inner_kw)
        seq = P(None, axis)
        n_loc = N // p

        if block_causal:
            # the block-causal rule depends on GLOBAL query position; the
            # shard offset is traced (axis_index), which a kernel grid
            # cannot take — so the sharded path computes the branch with
            # the reference math + pos0 (exact parity with inner="jnp")
            from repro.core.branches import chunked_q_attention, repeat_kv

            def body(q, k, v, kv):
                pos0 = jax.lax.axis_index(axis) * n_loc
                rep = q.shape[2] // k.shape[2]
                return chunked_q_attention(
                    q, repeat_kv(k, rep), repeat_kv(v, rep), key_valid=kv,
                    block_causal_ell=ell, chunk=chunk_tokens, pos0=pos0)
        else:
            def body(q, k, v, kv):
                kw = dict(inner_kw)
                if "q_valid" in kw:
                    kw["q_valid"] = None   # global hint, wrong per shard
                return inner.flash(q, k, v, key_valid=kv, ell=ell,
                                   chunk_tokens=chunk_tokens, **kw)

        # non-causal flash is the compression branch: K/V are the T/ℓ-small
        # pooled blocks, so replicating them is cheap by construction
        return _shard_call(mesh, body,
                           [(q, seq), (k, P()), (v, P()),
                            (key_valid, P())], seq)

    def selection(self, q, k, v, top_idx, sel_valid, mask, *, block_size,
                  group_size, chunk_tokens=0, q_valid=None):
        mesh, axis = self._require_mesh("selection")
        inner = self._resolve_inner()
        N, L, G = q.shape[1], k.shape[1], top_idx.shape[1]
        # ring selection shards K/V too, so the sequence must split in
        # block-size granules and the group axis in equal per-shard counts
        p = self._plan("selection", mesh, axis, N,
                       ring.lcm(block_size, N // G)) if N == L else None
        if N != L:
            _warn_once("selection", "qk-mismatch",
                       f"q len {N} != k len {L}; ring rotation needs "
                       "aligned sequence slabs")
        if p is not None and G % p:
            _warn_once("selection", "groups-indivisible",
                       f"G={G} not divisible by {axis!r}={p}")
            p = None
        if p is None:
            return inner.selection(q, k, v, top_idx, sel_valid, mask,
                                   block_size=block_size,
                                   group_size=group_size,
                                   chunk_tokens=chunk_tokens)
        if mask is None:
            mask = jnp.ones(q.shape[:2], bool)
        qv = mask if q_valid is None else q_valid
        seq = P(None, axis)
        g = N // G

        def body(q, ti, sv, k, v, m, qv):
            return ring.ring_selection(q, k, v, ti, sv, m, qv, axis=axis,
                                       p=p, block_size=block_size,
                                       group_size=g)

        return _shard_call(
            mesh, body,
            [(q, seq), (top_idx, seq), (sel_valid, seq),
             (k, seq), (v, seq),       # K/V stay sharded and rotate
             (mask, seq),              # key-token validity: local slab
             (qv, seq)],               # query validity: this shard's slice
            seq)

    # -- packed-varlen ops: LPT segment sharding ----------------------------

    def _varlen_layouts(self, plan, dig, total, pad_to):
        idx, loff, _, shift = ring.axis_layout(plan, dig, total, pad_to)
        return idx, jnp.asarray(loff), shift

    def ball_varlen(self, q, k, v, offsets, mask, *, ball_size,
                    chunk_tokens=0):
        mesh, axis = self._require_mesh("ball_varlen")
        inner = self._resolve_inner()
        planned = self._segment_plan("ball_varlen", mesh, axis, offsets,
                                     granules=(ball_size,))
        op = get_varlen(inner, "ball")
        if planned is None:
            return op(q, k, v, offsets, mask, ball_size=ball_size,
                      chunk_tokens=chunk_tokens)
        p, plan, dig = planned
        T = q.shape[0]
        idx, loff, _ = self._varlen_layouts(plan, dig, T, ball_size)
        qs, ks, vs = (ring.split_tokens(idx, a, p) for a in (q, k, v))
        ms = None if mask is None else ring.split_tokens(idx, mask, p)
        sp = P(axis)

        def body(q, k, v, m, lo):
            out = op(q[0], k[0], v[0], lo[0],
                     None if m is None else m[0],
                     ball_size=ball_size, chunk_tokens=chunk_tokens)
            return out[None]

        parts = _shard_call(mesh, body,
                            [(qs, sp), (ks, sp), (vs, sp), (ms, sp),
                             (loff, sp)], sp)
        return ring.merge_tokens(idx, parts, T)

    def flash_varlen(self, q, k, v, q_offsets, k_offsets, *, key_valid=None,
                     chunk_tokens=0):
        """Compression-branch varlen flash, segment-sharded on BOTH axes.

        The pooled key axis is laid out with the SAME sample→shard
        assignment as the query axis, so every query's keys are resident —
        this is the ring schedule with only hop 0 live, i.e. zero
        collectives."""
        from repro.kernels.occupancy import offsets_digest

        mesh, axis = self._require_mesh("flash_varlen")
        inner = self._resolve_inner()
        op = get_varlen(inner, "flash")
        p = mesh.shape[axis]
        qd, kd = offsets_digest(q_offsets), offsets_digest(k_offsets)
        if p == 1 or qd is None or kd is None:
            if p > 1:
                _warn_once("flash_varlen", "traced-offsets",
                           "offsets are traced (jitted without concrete "
                           "boundaries); the LPT segment partition is a "
                           "host-side decision")
            return op(q, k, v, q_offsets, k_offsets, key_valid=key_valid,
                      chunk_tokens=chunk_tokens)
        plan = ring.plan_segments(qd, p)
        Tq, Lk = q.shape[0], k.shape[0]
        qidx, qloff, _ = self._varlen_layouts(plan, qd, Tq, 1)
        kidx, kloff, _ = self._varlen_layouts(plan, kd, Lk, 1)
        qs = ring.split_tokens(qidx, q, p)
        ks, vs = (ring.split_tokens(kidx, a, p) for a in (k, v))
        kvs = (None if key_valid is None
               else ring.split_tokens(kidx, key_valid, p))
        sp = P(axis)

        def body(q, k, v, kv, qlo, klo):
            out = op(q[0], k[0], v[0], qlo[0], klo[0],
                     key_valid=None if kv is None else kv[0],
                     chunk_tokens=chunk_tokens)
            return out[None]

        parts = _shard_call(mesh, body,
                            [(qs, sp), (ks, sp), (vs, sp), (kvs, sp),
                             (qloff, sp), (kloff, sp)], sp)
        return ring.merge_tokens(qidx, parts, Tq)

    def local_window_varlen(self, q, k, v, offsets, *, window, mask=None,
                            chunk_tokens=0):
        mesh, axis = self._require_mesh("local_window_varlen")
        inner = self._resolve_inner()
        planned = self._segment_plan("local_window_varlen", mesh, axis,
                                     offsets, granules=(window,))
        op = get_varlen(inner, "local_window")
        if planned is None:
            return op(q, k, v, offsets, window=window, mask=mask,
                      chunk_tokens=chunk_tokens)
        p, plan, dig = planned
        T = q.shape[0]
        idx, loff, _ = self._varlen_layouts(plan, dig, T, window)
        qs, ks, vs = (ring.split_tokens(idx, a, p) for a in (q, k, v))
        ms = None if mask is None else ring.split_tokens(idx, mask, p)
        sp = P(axis)

        def body(q, k, v, m, lo):
            out = op(q[0], k[0], v[0], lo[0], window=window,
                     mask=None if m is None else m[0],
                     chunk_tokens=chunk_tokens)
            return out[None]

        parts = _shard_call(mesh, body,
                            [(qs, sp), (ks, sp), (vs, sp), (ms, sp),
                             (loff, sp)], sp)
        return ring.merge_tokens(idx, parts, T)

    def selection_varlen(self, q, k, v, top_idx, sel_valid, offsets, mask, *,
                         block_size, group_size, chunk_tokens=0):
        """Segment-sharded varlen selection.

        Selection never crosses samples (the scores mask enforces it), so
        after the LPT re-layout every group's selected blocks are resident
        on its own shard — the global block indices just need re-basing by
        the per-sample shift.  Needs sample sizes divisible by
        lcm(block, group) so block and group boundaries survive the move."""
        import numpy as np

        mesh, axis = self._require_mesh("selection_varlen")
        inner = self._resolve_inner()
        gran = ring.lcm(block_size, group_size)
        planned = self._segment_plan("selection_varlen", mesh, axis, offsets,
                                     granules=(gran,))
        op = get_varlen(inner, "selection")
        if planned is None:
            return op(q, k, v, top_idx, sel_valid, offsets, mask,
                      block_size=block_size, group_size=group_size,
                      chunk_tokens=chunk_tokens)
        p, plan, dig = planned
        T, G = q.shape[0], top_idx.shape[0]
        idx, loff, shift = self._varlen_layouts(plan, dig, T, gran)
        gdig = tuple(o // group_size for o in dig)
        gidx, _, _ = self._varlen_layouts(plan, gdig, G, gran // group_size)
        # per-group block-index shift: groups [off[s]/g, off[s+1]/g) belong
        # to sample s, whose blocks moved by shift[s]/ℓ
        gshift = np.zeros(G, np.int32)
        for s in range(len(dig) - 1):
            gshift[gdig[s]:gdig[s + 1]] = shift[s] // block_size
        ti = top_idx + jnp.asarray(gshift)[:, None, None]
        tis = ring.split_tokens(gidx, ti, p)
        svs = ring.split_tokens(gidx, sel_valid, p)
        qs, ks, vs = (ring.split_tokens(idx, a, p) for a in (q, k, v))
        ms = None if mask is None else ring.split_tokens(idx, mask, p)
        sp = P(axis)

        def body(q, k, v, ti, sv, m, lo):
            out = op(q[0], k[0], v[0], ti[0], sv[0], lo[0],
                     None if m is None else m[0],
                     block_size=block_size, group_size=group_size,
                     chunk_tokens=chunk_tokens)
            return out[None]

        parts = _shard_call(mesh, body,
                            [(qs, sp), (ks, sp), (vs, sp), (tis, sp),
                             (svs, sp), (ms, sp), (loff, sp)], sp)
        return ring.merge_tokens(idx, parts, T)


# ---------------------------------------------------------------------------
# Sequence-sharded paged decode (ServingEngine integration)
# ---------------------------------------------------------------------------

class _ShardedPoolOps:
    """Row-partitioned pool access for the paged decode.

    Pools are split along dim 0 into contiguous row blocks, one per shard.
    Gathers read OOB-safe locally (``mode="fill"`` zeros for rows another
    shard owns) and psum — exact, since every row has one nonzero
    contributor.  Scatters drop non-owned rows (``mode="drop"``), so each
    row is written only by its owner and no collective is needed.
    ``cmp_attend`` merges per-shard softmax statistics instead of gathering
    the compressed rows (the ring merge at hop count 1)."""

    def __init__(self, axis: str):
        self.axis = axis

    def _local(self, pool, rows):
        # rows this shard does not own map to r_loc — PAST the local end, so
        # fill/drop modes treat them as OOB.  (A bare negative index would
        # WRAP per Python indexing semantics before the OOB check.)
        r_loc = pool.shape[0]
        li = rows - jax.lax.axis_index(self.axis) * r_loc
        return jnp.where((li >= 0) & (li < r_loc), li, r_loc)

    def gather(self, pool, rows):
        g = pool.at[self._local(pool, rows)].get(mode="fill", fill_value=0)
        return jax.lax.psum(g, self.axis)

    def gather_head(self, pool, rows, head_idx):
        hb = jnp.broadcast_to(head_idx, rows.shape)
        g = pool.at[self._local(pool, rows), hb].get(mode="fill",
                                                     fill_value=0)
        return jax.lax.psum(g, self.axis)

    def scatter_rows(self, pool, rows, vals):
        return pool.at[self._local(pool, rows)].set(vals.astype(pool.dtype),
                                                    mode="drop")

    def cmp_attend(self, k_pool, v_pool, rows, q1, blk_ok, rep):
        """Compression attention + selection scores over OWNED rows only.

        Each shard attends the compressed rows it holds (non-owned rows
        masked NEG_INF) and the per-query (m, l, acc) triples are merged
        with a pmax/psum — O(B·Hq·D) on the wire instead of the
        O(B·NB·Hkv·D) all-gather of the row values.  Exact up to fp
        reassociation: every row is owned by exactly one shard, so the
        shard partials partition the key set.  The selection scores ride
        the same local reads (zero-filled non-owned rows psum exactly)."""
        from repro.core.nsa_causal import _cmp_attend_from_rows
        from repro.numerics import NEG_INF, mask_to_bias
        from repro.core.branches import repeat_kv

        if os.environ.get("REPRO_SHARDED_RING_DECODE", "1") == "0":
            return _cmp_attend_from_rows(self.gather(k_pool, rows),
                                         self.gather(v_pool, rows),
                                         q1, blk_ok, rep)
        B, _, Hq, D = q1.shape
        Hkv = k_pool.shape[1]
        li = self._local(k_pool, rows)
        owned = li < k_pool.shape[0]                               # (B, NB)
        kl = k_pool.at[li].get(mode="fill", fill_value=0)          # (B,NB,Hkv,D)
        vl = v_pool.at[li].get(mode="fill", fill_value=0)
        # selection scores: zero-filled non-owned rows contribute 0 → psum
        # reassembles the exact dense q·k row scores
        qg = q1.reshape(B, 1, Hkv, rep, D)
        s = jnp.einsum("bmkrd,bnkd->bkn", qg.astype(jnp.float32),
                       kl.astype(jnp.float32),
                       preferred_element_type=jnp.float32) / (D ** 0.5)
        s = jax.lax.psum(jnp.where(owned[:, None, :], s, 0.0), self.axis)
        s = jnp.where(blk_ok[:, None, :], s, NEG_INF)
        # compression attention: local partial stats, merged across shards
        qh = q1.transpose(0, 2, 1, 3)                              # (B,Hq,1,D)
        bias = mask_to_bias((blk_ok & owned)[:, None, None, :])
        logits = jnp.einsum(
            "bhnd,bhld->bhnl", qh,
            repeat_kv(kl, rep).transpose(0, 2, 1, 3),
            preferred_element_type=jnp.float32) / (D ** 0.5) + bias
        m = logits.max(-1)                                         # (B,Hq,1)
        pw = jnp.exp(logits - m[..., None])
        pw = jnp.where(logits <= NEG_INF / 2, 0.0, pw)
        l = pw.sum(-1)
        acc = jnp.einsum("bhnl,bhld->bhnd", pw,
                         repeat_kv(vl, rep).transpose(0, 2, 1, 3)
                         .astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, self.axis)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, self.axis)
        acc_g = jax.lax.psum(acc * w[..., None], self.axis)
        out = (acc_g / jnp.maximum(l_g, 1e-20)[..., None]).astype(v_pool.dtype)
        return out, s


def sharded_paged_decode(backend, params, q1, k1, v1, cache, table,
                         lengths, *, cfg, page, x1=None):
    """One paged NSA decode step with KV pools partitioned across the mesh.

    Called from ``core.nsa_causal.nsa_causal_decode_paged`` when the
    resolved backend is sharded.  The whole step runs under one
    ``shard_map``: pools enter/leave row-sharded (``P(axis)``), everything
    else (query, table, lengths, params) is replicated, and the attention
    output is identical on every shard (gathers psum; the compression
    branch merges softmax stats instead — see ``_ShardedPoolOps``).
    Requires the pool row counts R and Rc to divide the mesh axis;
    otherwise falls back to the dense single-device pool ops under the
    inner backend.
    """
    from repro.core import nsa_causal
    from repro.core.backend import get_paged_gather

    mesh, axis = backend._require_mesh("paged decode")
    inner = backend._resolve_inner()
    p = mesh.shape[axis]
    R, Rc = cache["k"].shape[0], cache["k_cmp"].shape[0]
    if p == 1 or R % p or Rc % p:
        if p > 1:
            _warn_once("paged decode", "pool-rows-indivisible",
                       f"pool rows R={R}/Rc={Rc} not divisible by "
                       f"{axis!r}={p}")
        ops = nsa_causal._DensePoolOps(get_paged_gather(inner))
        return nsa_causal.nsa_causal_decode_paged(
            params, q1, k1, v1, cache, table, lengths, cfg=cfg, page=page,
            x1=x1, _pool_ops=ops)

    pool_ops = _ShardedPoolOps(axis)
    pool_spec = {name: P(axis) for name in cache}

    def body(params, q1, k1, v1, cache, table, lengths, x1):
        return nsa_causal.nsa_causal_decode_paged(
            params, q1, k1, v1, cache, table, lengths, cfg=cfg, page=page,
            x1=x1, _pool_ops=pool_ops)

    args = [(params, P()), (q1, P()), (k1, P()), (v1, P()),
            (cache, pool_spec), (table, P()), (lengths, P()), (x1, P())]
    arrs = [a for a, _ in args if a is not None]
    specs = tuple(s for a, s in args if a is not None)
    present = [a is not None for a, _ in args]

    def wrapper(*xs):
        it = iter(xs)
        return body(*[next(it) if pr else None for pr in present])

    return shard_map(wrapper, mesh=mesh, in_specs=specs,
                     out_specs=(P(), pool_spec), check_rep=False)(*arrs)


if "sharded" not in list_backends():       # idempotent on re-import paths
    register_backend("sharded", ShardedBackend())
