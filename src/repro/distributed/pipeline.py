"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

For deployments deeper than 2 pods the layer stack splits into S stages;
microbatches stream through with ``jax.lax.ppermute`` handoffs inside
``shard_map``.  T = n_micro + S − 1 ticks; stage s computes microbatch
m = t − s when 0 ≤ m < n_micro (the usual fill/drain bubble, fraction
(S−1)/T).  Stage weights live only on their stage's devices.

This module is self-contained (the production dry-run mesh uses DP×TP×SP —
BSA workloads are attention- not depth-bound; see DESIGN §4) and is
unit-tested for exactness against the sequential reference on a 4-way mesh.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh, axis_name="stage"):
    """Run a pipelined stack.

    stage_fn(params_s, x) -> y   (same shape), applied S times in sequence;
    stage_params: pytree with leading STAGE dim S on every leaf;
    x_micro: (n_micro, B, ...) microbatches.
    Returns (n_micro, B, ...) outputs, exactly stage_{S-1}∘…∘stage_0.
    """
    S = mesh.shape[axis_name]
    n_micro = x_micro.shape[0]
    T = n_micro + S - 1

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1) ; xs: all microbatches
        params = jax.tree.map(lambda t: t[0], params)
        sid = jax.lax.axis_index(axis_name)
        buf = jnp.zeros_like(xs[0])                  # inter-stage register
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            m = t - sid                               # microbatch index at stage
            active = (m >= 0) & (m < n_micro)
            # stage 0 reads fresh input; others read the handoff register
            x_in = jnp.where(sid == 0,
                             xs[jnp.clip(m, 0, n_micro - 1)], buf)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # last stage writes output
            outs = jnp.where(
                (sid == S - 1) & active,
                outs.at[jnp.clip(m, 0, n_micro - 1)].set(y), outs)
            # hand off to next stage
            buf_next = jax.lax.ppermute(
                y, axis_name, [(i, (i + 1) % S) for i in range(S)])
            return buf_next, outs

        buf, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # outputs live on the last stage; psum broadcasts them to all stages
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis_name)
        return outs

    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(P(axis_name), P()),
                   out_specs=P(), check_rep=False)
    return fn(stage_params, x_micro)
