from repro.distributed.sharding import (  # noqa: F401
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
)
from repro.distributed.sharded_backend import (  # noqa: F401
    ShardedBackend,
    current_mesh_axis,
    mesh_context,
)
