from repro.distributed.sharding import (  # noqa: F401
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
)
from repro.distributed.ring import (  # noqa: F401  (before sharded_backend:
    SegmentPlan,                      # it imports repro.distributed.ring)
    axis_layout,
    lpt_partition,
    plan_segments,
    ring_flash,
    ring_perm,
    ring_selection,
    round_robin_partition,
)
from repro.distributed.sharded_backend import (  # noqa: F401
    ShardedBackend,
    current_mesh_axis,
    mesh_context,
)
