from repro.distributed.sharding import (  # noqa: F401
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
)
