import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: sharding
propagates, the collective schedule exists, and memory_analysis shows the
per-device footprint fits HBM.  Emits one JSON per cell under results/dryrun/
(resumable: cells with an existing JSON are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.distributed.params import (
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
)
from repro.distributed.sharding import axis_rules
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.api import model_api
from repro.optim import adamw_init

HBM_PER_CHIP = 16 * 1024**3  # v5e


def _dp_size(mesh):
    return int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.shape]))


def shape_rules(mcfg, shape, mesh):
    """Logical-axis rule overrides for a given cell."""
    rules = {}
    seq_parallel = shape.global_batch < _dp_size(mesh)
    if seq_parallel:
        rules["seq"] = ("data",)
    if mcfg.attn_shard_mode == "sequence":
        # ball-parallel attention (e.g. llava: 56 heads ∤ 16) — shard seq over
        # model for activations; params keep their TP layout.
        rules["seq"] = ("model",) if not seq_parallel else ("data", "model")
    return rules, seq_parallel


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               backend: str | None = None):
    mcfg = get_config(arch)
    if backend:
        import dataclasses
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, backend=backend))
    shape = SHAPES[shape_name]
    api = model_api(mcfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules, seq_parallel = shape_rules(mcfg, shape, mesh)

    B, N = shape.global_batch, shape.seq_len
    params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = param_shardings(params_struct, mesh, zero1=mcfg.fsdp)

    with mesh, axis_rules(mesh, rules):
        if shape.kind == "train":
            opt_struct = jax.eval_shape(
                lambda p: adamw_init(p, state_dtype=jnp.dtype(mcfg.opt_state_dtype)),
                params_struct)
            o_sh = opt_shardings(opt_struct, mesh)
            bspec = api.batch_specs(B, N)
            b_sh = batch_shardings(bspec, mesh, seq_parallel=seq_parallel)
            step = make_train_step(api)
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                              donate_argnums=(0, 1)).lower(
                params_struct, opt_struct, bspec)
        elif shape.kind == "prefill":
            bspec = api.batch_specs(B, N)
            b_sh = batch_shardings(bspec, mesh, seq_parallel=seq_parallel)
            step = make_prefill_step(api)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                params_struct, bspec)
        else:  # decode
            cspec = api.cache_specs(B, N)
            c_sh = cache_shardings(cspec, mesh, seq_parallel=seq_parallel)
            tok = jax.ShapeDtypeStruct((B,), jnp.int32)
            t_sh = batch_shardings(tok, mesh, seq_parallel=False)
            step = make_serve_step(api)
            lowered = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                              donate_argnums=(1,)).lower(
                params_struct, cspec, tok)
    return lowered, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, backend: str | None = None) -> dict:
    mesh_name = "pod2" if multi_pod else "pod1"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        lowered, mesh = lower_cell(arch, shape_name, multi_pod, backend=backend)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        hh = analyze_hlo(hlo)
        # persist the HLO so the analysis can be re-run without recompiling
        hlo_dir = out_dir / "hlo"
        hlo_dir.mkdir(parents=True, exist_ok=True)
        import gzip
        with gzip.open(hlo_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.gz",
                       "wt") as f:
            f.write(hlo)
        coll = hh["collectives"]
        n_dev = mesh.size

        args_b = int(ma.argument_size_in_bytes)
        temp_b = int(ma.temp_size_in_bytes)
        out_b = int(ma.output_size_in_bytes)
        alias_b = int(ma.alias_size_in_bytes)
        peak = args_b + temp_b + out_b - alias_b
        # XLA-CPU emulates bf16 dots via f32 COPIES of bf16 operands — temp
        # buffers that do not exist on TPU (native bf16 MXU).  The TPU
        # estimate subtracts them; both numbers are recorded.
        upcast = min(int(hh["bf16_upcast_bytes"]), temp_b)
        peak_tpu = max(peak - upcast, args_b + out_b)
        rec.update({
            "ok": True,
            "n_devices": n_dev,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            # memory_analysis is PER DEVICE
            "argument_bytes": args_b,
            "output_bytes": out_b,
            "temp_bytes": temp_b,
            "alias_bytes": alias_b,
            "peak_bytes": peak,
            "bf16_upcast_bytes": upcast,
            "peak_bytes_tpu_est": peak_tpu,
            "fits_hbm": bool(peak_tpu <= HBM_PER_CHIP),
            # cost_analysis is PER DEVICE but counts while bodies ONCE —
            # kept for reference; the loop-WEIGHTED numbers below are the
            # roofline inputs (see launch/hlo_analysis.py)
            "flops_per_device_unweighted": float(ca.get("flops", -1)),
            "bytes_per_device_unweighted": float(ca.get("bytes accessed", -1)),
            "flops_per_device": hh["dot_flops_weighted"],
            "traffic_bytes_per_device": hh["traffic_bytes_weighted"],
            "collectives": coll,
            "collective_wire_bytes": hh["collective_wire_bytes"],
        })
        # human-readable print per spec
        print(f"[{arch} × {shape_name} × {mesh_name}] compile {rec['compile_s']}s  "
              f"peak/dev {peak/2**30:.2f} GiB (tpu-est {peak_tpu/2**30:.2f})  "
              f"fits={rec['fits_hbm']}  flops/dev {rec['flops_per_device']:.3e}  "
              f"coll {rec['collective_wire_bytes']/2**20:.1f} MiB", flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue the matrix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{arch} × {shape_name} × {mesh_name}] FAILED: {rec['error']}",
              flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="attention backend override for every cell: jnp | "
                         "pallas | interpret | auto (default: config)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir, force=args.force,
                               backend=args.backend)
                n_ok += bool(rec.get("ok"))
                n_fail += not rec.get("ok")
                jax.clear_caches()  # bound host RAM across the 80-cell matrix
    print(f"\ndry-run matrix: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
