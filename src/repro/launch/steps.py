"""Step functions: train_step (fwd+bwd+AdamW), prefill_step, serve_step.

These are THE functions the dry-run lowers and the trainer/server jit.
"""

from __future__ import annotations


import contextlib

import jax
import jax.numpy as jnp

from repro.optim import adamw_update, clip_by_global_norm, cosine_schedule


def make_train_step(api, *, base_lr=1e-3, weight_decay=0.01, total_steps=100_000,
                    warmup_steps=1000, max_grad_norm=1.0, mesh_info=None):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    ``mesh_info`` — an optional ``(mesh, axis)`` pair.  When given, the loss
    (and its backward) is traced inside :func:`mesh_context`, so a
    ``"sharded"`` backend resolves the mesh even when the step is jitted
    from a scope that no longer holds the context (trainers capture the
    mesh once at build time, same as ``ServingEngine``)."""

    def _scope():
        if mesh_info is None:
            return contextlib.nullcontext()
        from repro.distributed import mesh_context
        return mesh_context(mesh_info[0], axis=mesh_info[1])

    def train_step(params, opt_state, batch):
        with _scope():
            (loss, metrics), grads = jax.value_and_grad(
                api.loss, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_schedule(opt_state["step"], base_lr=base_lr,
                             total_steps=total_steps, warmup_steps=warmup_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=weight_decay)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: v for k, v in metrics.items() if v.ndim == 0})
        return params, opt_state, out

    return train_step


def make_prefill_step(api):
    """Forward pass returning LAST-position logits (B, V) — lowering the full
    (B, N, V) logits tensor would dominate memory for 200k vocabs."""

    def prefill_step(params, batch):
        out = api.forward(params, batch)
        return out[:, -1].astype(jnp.float32)

    return prefill_step


def make_serve_step(api, *, greedy: bool = True):
    """(params, caches, token (B,)) → (next_token (B,), logits (B,V), caches)."""

    def serve_step(params, caches, token):
        logits, caches = api.decode_step(params, token, caches)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return serve_step


def make_paged_serve_step(api, *, page: int):
    """The continuous-batching decode step (block-table addressing).

    (params, caches, token (B,), table (B, n_pages) int32, lengths (B,
    int32)) → (next_token (B,), logits (B, V), caches).  ``page`` is static
    (baked into the jit); the tiny table/lengths arrays are pushed from the
    host scheduler each call, so ONE compiled step serves every admission /
    retirement configuration."""

    def paged_serve_step(params, caches, token, table, lengths):
        logits, caches = api.paged_decode_step(params, token, caches, table,
                                               lengths, page)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return paged_serve_step


def make_paged_serve_window(api, *, page: int):
    """W greedy continuous-batching steps in ONE compiled call (lax.scan).

    Between host scheduling events (admission, retirement) a greedy
    schedule is VALUE-independent, so the engine batches W decode steps per
    dispatch instead of paying host round-trip latency per token.  Per-step
    feeds are data: ``feed (W, B)`` holds prompt tokens and ``use_prev
    (W, B)`` flips a slot to self-feeding (its previous sample) once its
    prompt is exhausted — the prefill→decode transition happens mid-window
    with no host involvement.  ``occ (B,) int32`` advances only occupied
    slots' lengths; W is baked into the compiled shape (the engine
    quantizes it to powers of two so at most log₂(W_max)+1 variants ever
    compile).

    (params, caches, feed (W, B) int32, use_prev (W, B) bool, prev (B,)
    int32, table (B, n_pages) int32, lengths (B,) int32, occ (B,) int32)
    → (samples (W, B) int32, caches)."""

    def paged_serve_window(params, caches, feed, use_prev, prev, table,
                           lengths, occ):
        def body(carry, xs):
            caches, prev, lengths = carry
            feed_t, use_t = xs
            tok = jnp.where(use_t, prev, feed_t)
            logits, caches = api.paged_decode_step(params, tok, caches,
                                                   table, lengths, page)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (caches, nxt, lengths + occ), nxt

        (caches, _, _), samples = jax.lax.scan(
            body, (caches, prev, lengths), (feed, use_prev))
        return samples, caches

    return paged_serve_window
