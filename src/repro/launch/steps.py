"""Step functions: train_step (fwd+bwd+AdamW), prefill_step, serve_step.

These are THE functions the dry-run lowers and the trainer/server jit.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.optim import adamw_update, clip_by_global_norm, cosine_schedule


def make_train_step(api, *, base_lr=1e-3, weight_decay=0.01, total_steps=100_000,
                    warmup_steps=1000, max_grad_norm=1.0):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(
            params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_schedule(opt_state["step"], base_lr=base_lr,
                             total_steps=total_steps, warmup_steps=warmup_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=weight_decay)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: v for k, v in metrics.items() if v.ndim == 0})
        return params, opt_state, out

    return train_step


def make_prefill_step(api):
    """Forward pass returning LAST-position logits (B, V) — lowering the full
    (B, N, V) logits tensor would dominate memory for 200k vocabs."""

    def prefill_step(params, batch):
        out = api.forward(params, batch)
        return out[:, -1].astype(jnp.float32)

    return prefill_step


def make_serve_step(api, *, greedy: bool = True):
    """(params, caches, token (B,)) → (next_token (B,), logits (B,V), caches)."""

    def serve_step(params, caches, token):
        logits, caches = api.decode_step(params, token, caches)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches

    return serve_step
