"""HLO text analysis for the roofline: loop-weighted FLOPs, HBM traffic and
collective bytes.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop BODY
exactly once, but our layer stacks compile to while loops (scan) that run
n_periods times — flops/bytes/collectives must be weighted by trip counts or
a 52-layer model looks like a 1-layer model.  Trip counts come from the
``backend_config={"known_trip_count":{"n":...}}`` annotation XLA attaches to
while ops (fallback: the s32 limit constant in the loop condition).

All numbers are PER DEVICE (we parse the post-SPMD partitioned module).
"""

from __future__ import annotations

import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# op name = first lowercase word followed by "(" — layout tiles like
# ":T(8,128)(2,1)" and tuple comments "/*index=5*/" never match (uppercase /
# preceded by ":" / no paren), so this survives arbitrary tuple types.
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\(")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.instr_type: dict[str, str] = {}
        cur, lines = None, []
        for line in text.splitlines():
            s = line.strip()
            if s.endswith("{") and ("->" in s) and not s.startswith("//"):
                m = _HEADER_RE.match(s)
                if m:
                    cur, lines = m.group(1), []
                    continue
            if s.startswith("}"):
                if cur is not None:
                    self.computations[cur] = lines
                cur = None
                continue
            if cur is not None:
                lines.append(s)
                im = _INSTR_RE.match(s)
                if im:
                    self.instr_type[im.group(1)] = im.group(2)

        self.mult = self._multipliers()

    def _multipliers(self) -> dict[str, int]:
        edges: list[tuple[str, str, int]] = []
        for cname, lines in self.computations.items():
            for s in lines:
                im = _INSTR_RE.match(s)
                if not im:
                    continue
                op = im.group(3)
                if op == "while":
                    trips = 1
                    tm = _TRIP_RE.search(s)
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        cm = _COND_RE.search(s)
                        if cm:
                            cond = "\n".join(self.computations.get(cm.group(1), []))
                            consts = [int(c) for c in
                                      re.findall(r"s32\[\]\s+constant\((\d+)\)", cond)]
                            trips = max(consts) if consts else 1
                    bm = _BODY_RE.search(s)
                    if bm:
                        edges.append((cname, bm.group(1), trips))
                    cm = _COND_RE.search(s)
                    if cm:
                        edges.append((cname, cm.group(1), trips))
                else:
                    for callee in _CALLS_RE.findall(s):
                        edges.append((cname, callee, 1))
        mult: dict[str, int] = defaultdict(lambda: 0)
        # roots: computations never called
        called = {c for _, c, _ in edges}
        for cname in self.computations:
            if cname not in called:
                mult[cname] = 1
        for _ in range(8):  # fixpoint over shallow nesting
            changed = False
            for parent, child, trips in edges:
                cand = mult[parent] * max(trips, 1)
                if cand > mult[child]:
                    mult[child] = cand
                    changed = True
            if not changed:
                break
        return dict(mult)

    # -- analyses ----------------------------------------------------------

    def collectives(self) -> dict:
        out = {c: {"bytes": 0, "count": 0} for c in COLLECTIVES}
        for cname, lines in self.computations.items():
            m = self.mult.get(cname, 1)
            for s in lines:
                im = _INSTR_RE.match(s)
                if not im:
                    continue
                op = im.group(3)
                base = op[:-6] if op.endswith("-start") else op
                if base in COLLECTIVES and not op.endswith("-done"):
                    out[base]["bytes"] += _type_bytes(im.group(2)) * m
                    out[base]["count"] += m
        return out

    def dot_flops(self) -> float:
        """2 × result_elems × contraction_size per dot, loop-weighted."""
        total = 0.0
        for cname, lines in self.computations.items():
            m = self.mult.get(cname, 1)
            for s in lines:
                im = _INSTR_RE.match(s)
                if not im or im.group(3) not in ("dot", "convolution"):
                    continue
                res_dims = _shape_dims(im.group(2))
                res_elems = 1
                for d in res_dims:
                    res_elems *= d
                if im.group(3) == "dot":
                    lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
                    # lhs operand type lookup
                    ops = _OPERAND_RE.findall(s.split("dot(", 1)[1])
                    k = 1
                    if lc and ops:
                        lhs_t = self.instr_type.get(ops[0], "")
                        ldims = _shape_dims(lhs_t)
                        for ci in lc.group(1).split(","):
                            if ci and int(ci) < len(ldims):
                                k *= ldims[int(ci)]
                    total += 2.0 * res_elems * k * m
                else:  # convolution: ≈ 2 × out × kernel_spatial × in_per_group
                    km = re.search(r"window=\{size=([\dx]+)", s)
                    ksz = 1
                    if km:
                        for d in km.group(1).split("x"):
                            ksz *= int(d)
                    total += 2.0 * res_elems * ksz * m
        return total

    def traffic_bytes(self) -> float:
        """Approximate HBM traffic: Σ (result + operand bytes) over top-level
        (non-fused-subcomputation) instructions, loop-weighted.  Fusion
        callees are skipped — the fusion op itself carries the traffic."""
        fused = set()
        for cname, lines in self.computations.items():
            for s in lines:
                for callee in _CALLS_RE.findall(s):
                    if "fusion(" in s or "kind=kLoop" in s or "kind=kInput" in s \
                            or "kind=kOutput" in s:
                        fused.add(callee)
        skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "after-all", "partition-id", "replica-id"}
        total = 0.0
        for cname, lines in self.computations.items():
            if cname in fused:
                continue
            m = self.mult.get(cname, 1)
            for s in lines:
                im = _INSTR_RE.match(s)
                if not im or im.group(3) in skip_ops:
                    continue
                b = _type_bytes(im.group(2))
                args = s.split("(", 1)[1] if "(" in s else ""
                args = args.split("), ")[0]
                for opn in _OPERAND_RE.findall(args):
                    b += _type_bytes(self.instr_type.get(opn, ""))
                total += b * m
        return total


    def bf16_upcast_bytes(self, min_bytes: int = 16 * 2**20) -> int:
        """XLA-CPU emulates bf16 dots by materialising f32 COPIES of bf16
        operands (weights, KV caches) — temp buffers that do NOT exist on
        TPU, where bf16 matmul is native.  Sum of large f32 results whose
        single operand is an identically-shaped bf16 tensor; used to correct
        the per-device peak-memory estimate (documented in EXPERIMENTS)."""
        total = 0
        seen = set()
        for cname, lines in self.computations.items():
            for s in lines:
                im = _INSTR_RE.match(s)
                if not im or im.group(3) not in ("convert", "fusion", "copy"):
                    continue
                res_t = im.group(2)
                if not res_t.startswith("f32["):
                    continue
                b = _type_bytes(res_t)
                if b < min_bytes:
                    continue
                args = s.split("(", 1)[1]
                ops = _OPERAND_RE.findall(args.split(")")[0])
                if len(ops) != 1:
                    continue
                src_t = self.instr_type.get(ops[0], "")
                if src_t.startswith("bf16[") and \
                        _shape_dims(src_t) == _shape_dims(res_t):
                    if im.group(1) not in seen:
                        seen.add(im.group(1))
                        total += b
        return total


def analyze_hlo(text: str) -> dict:
    mod = HloModule(text)
    coll = mod.collectives()
    return {
        "collectives": coll,
        "collective_wire_bytes": collective_wire_bytes(coll),
        "dot_flops_weighted": mod.dot_flops(),
        "traffic_bytes_weighted": mod.traffic_bytes(),
        "bf16_upcast_bytes": mod.bf16_upcast_bytes(),
    }


def analyze_collectives(hlo_text: str) -> dict:
    return HloModule(hlo_text).collectives()


def collective_wire_bytes(coll: dict) -> float:
    """Per-device wire bytes with ring factors: AR≈2×, others ≈1×."""
    total = 0.0
    for op, d in coll.items():
        factor = 2.0 if op == "all-reduce" else 1.0
        total += factor * d["bytes"]
    return total


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze_hlo(open(sys.argv[1]).read()), indent=1))
