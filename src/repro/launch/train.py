"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 --batch 8 --seq 256 --smoke          # CPU-sized
    python -m repro.launch.train --arch shapenet-bsa --steps 1000

On a real TPU pod slice this is the per-host entry point: jax.distributed
initializes from the TPU environment, the mesh comes from
``make_production_mesh()``, and every host feeds its local batch shard.
On CPU it runs single-process (optionally with a small fake mesh).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.configs.reduce import smoke_config
from repro.data import ShapeNetCarDataset, lm_batches
from repro.models.api import model_api
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--backend", default=None,
                    help="attention backend: jnp | pallas | interpret | auto "
                         "| any registered plug-in (default: config)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mesh", default="", help="e.g. 2x4 → (data=2, model=4)")
    ap.add_argument("--distributed", action="store_true",
                    help="jax.distributed.initialize() from TPU env")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    mcfg = get_config(args.arch)
    if args.smoke:
        mcfg = smoke_config(mcfg)
    if args.backend:
        import dataclasses
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, backend=args.backend))
    api = model_api(mcfg)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[:len(dims)]
        mesh = make_mesh(dims, names)

    cfg = TrainerConfig(base_lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1),
                        ckpt_dir=args.ckpt, log_every=max(args.steps // 20, 1))
    trainer = Trainer(api, cfg, mesh=mesh)

    if mcfg.family == "pointcloud":
        data = ShapeNetCarDataset("train").batches(args.batch, seed=0)
    else:
        data = lm_batches(vocab_size=mcfg.vocab_size, batch_size=args.batch,
                          seq_len=args.seq, seed=0)
    trainer.fit(data, steps=args.steps)
    print(f"done: {args.steps} steps, wall {trainer.wall_time:.1f}s, "
          f"stragglers {len(trainer.watchdog.straggler_events)}")


if __name__ == "__main__":
    main()
