"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Target: TPU v5e pods — 16×16 = 256 chips per pod; multi-pod adds a
leading ``pod`` axis (2 pods = 512 chips) whose collectives cross DCI.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; older jax only has Auto axes,
    # so omitting the kwarg there is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    have = len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"make_production_mesh targets a {'2-pod ' if multi_pod else ''}"
            f"16x16 v5e pod ({need} devices) but only {have} device(s) are "
            "present; use make_local_mesh() (or make_mesh() with an explicit "
            "shape) for smaller hosts")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh(n_data: int | None = None, *, axis: str = "data"):
    """1-D mesh over however many devices actually exist.

    The mesh the CPU smoke runs and the ``"sharded"`` attention backend use
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` fakes devices
    for CI).  ``n_data`` takes the first n devices; default is all of them.
    """
    have = len(jax.devices())
    n = n_data if n_data is not None else have
    if n < 1 or n > have:
        raise RuntimeError(
            f"make_local_mesh(n_data={n}): {have} device(s) present")
    return jax.make_mesh((n,), (axis,), devices=jax.devices()[:n],
                         **_axis_type_kwargs(1))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


# v5e hardware constants (per chip) — used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link


def ring_roofline_us(bytes_per_hop: int, hops: int,
                     links: int = 1) -> float:
    """ICI time (µs) of a ring-attention schedule on the roofline model.

    Each hop pushes one K/V slab to the ring neighbour over ``links`` ICI
    links; hops overlap with compute in steady state, so this is the lower
    bound the per-hop compute must exceed for the rotation to be free
    (``benchmarks/perf_iter.py --ring`` stamps it next to the measured
    ratios)."""
    return hops * bytes_per_hop / (links * ICI_BW_PER_LINK) * 1e6
