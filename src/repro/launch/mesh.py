"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Target: TPU v5e pods — 16×16 = 256 chips per pod; multi-pod adds a
leading ``pod`` axis (2 pods = 512 chips) whose collectives cross DCI.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; older jax only has Auto axes,
    # so omitting the kwarg there is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


# v5e hardware constants (per chip) — used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
