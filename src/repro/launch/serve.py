"""Serving launcher: batched greedy/temperature decode through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --slots 4 --tokens 64 --smoke

``--paged`` switches to continuous batching over the paged KV cache
(docs/serving.md): requests with RAGGED prompt lengths stream through the
slots, retiring on completion and admitting queued work mid-flight.

    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --requests 16 --tokens 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.reduce import smoke_config
from repro.models.api import model_api
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="attention backend: jnp | pallas | interpret | auto "
                         "| any registered plug-in (default: config)")
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over the paged KV cache")
    ap.add_argument("--requests", type=int, default=8,
                    help="(--paged) number of ragged requests to serve")
    ap.add_argument("--page", type=int, default=None,
                    help="(--paged) tokens per KV block (default: lcm of "
                         "local window and compression block)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="(--paged) KV pool size in blocks")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="(--paged) disable cross-request prefix reuse")
    args = ap.parse_args()

    mcfg = get_config(args.arch)
    if args.smoke:
        mcfg = smoke_config(mcfg)
    if args.backend:
        import dataclasses
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, backend=args.backend))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if args.paged:
        eng = ServingEngine(api, params, batch_slots=args.slots,
                            max_len=args.max_len,
                            temperature=args.temperature, paged=True,
                            page=args.page, num_blocks=args.num_blocks,
                            prefix_cache=not args.no_prefix_cache)
        lens = rng.integers(args.prompt_len // 2, args.prompt_len + 1,
                            args.requests)
        prompts = [rng.integers(0, mcfg.vocab_size, n, dtype=np.int32)
                   for n in lens]
        out = eng.serve(prompts, max_new_tokens=args.tokens)
        print(f"served {len(out)} requests in {eng.serve_steps} steps "
              f"(prompt lens {lens.min()}..{lens.max()}), throughput "
              f"{eng.tokens_per_second:.1f} tok/s, prefix blocks reused "
              f"{eng.kv.blocks_reused}, cow copies {eng.kv.cow_copies}")
        return
    eng = ServingEngine(api, params, batch_slots=args.slots,
                        max_len=args.max_len, temperature=args.temperature)
    prompts = rng.integers(0, mcfg.vocab_size, (args.slots, args.prompt_len),
                           dtype=np.int32)
    out = eng.generate(prompts, args.tokens)
    print("generated", out.shape, "throughput",
          f"{eng.tokens_per_second:.1f} tok/s")


if __name__ == "__main__":
    main()
