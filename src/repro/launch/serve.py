"""Serving launcher: batched greedy/temperature decode through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --slots 4 --tokens 64 --smoke
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.reduce import smoke_config
from repro.models.api import model_api
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="attention backend: jnp | pallas | interpret | auto "
                         "| any registered plug-in (default: config)")
    args = ap.parse_args()

    mcfg = get_config(args.arch)
    if args.smoke:
        mcfg = smoke_config(mcfg)
    if args.backend:
        import dataclasses
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, backend=args.backend))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(api, params, batch_slots=args.slots,
                        max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mcfg.vocab_size, (args.slots, args.prompt_len),
                           dtype=np.int32)
    out = eng.generate(prompts, args.tokens)
    print("generated", out.shape, "throughput",
          f"{eng.tokens_per_second:.1f} tok/s")


if __name__ == "__main__":
    main()
