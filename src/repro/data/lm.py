"""Synthetic LM token pipeline: Zipf-distributed tokens with short-range
Markov structure (so loss measurably decreases), deterministic and
restartable — the iterator state is a (seed, step) pair the checkpoint
manager can save/restore."""

from __future__ import annotations

import numpy as np


def lm_batches(*, vocab_size: int, batch_size: int, seq_len: int, seed: int = 0,
               start_step: int = 0):
    """Yields {tokens, labels} with labels = next-token shift."""
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        z = rng.zipf(1.3, (batch_size, seq_len + 1)).astype(np.int64)
        toks = (z % (vocab_size - 2)) + 1
        # inject deterministic bigram structure: even positions repeat
        toks[:, 2::2] = toks[:, 1:-1:2]
        toks = toks.astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:],
               "_state": {"seed": seed, "step": step}}
        step += 1
