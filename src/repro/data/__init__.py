from repro.data.shapenet import ShapeNetCarDataset  # noqa: F401
from repro.data.elasticity import ElasticityDataset  # noqa: F401
from repro.data.lm import lm_batches  # noqa: F401
