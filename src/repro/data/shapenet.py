"""Synthetic ShapeNet-Car–like dataset (airflow pressure regression).

The real ShapeNet-Car set (Umetani & Bickel 2018) is 889 cars × 3586 surface
points with RANS-simulated pressure at Re = 5×10⁶.  Offline we synthesise a
faithful PROXY with the same shapes and statistics: car-like bodies
(superellipsoid hull + cabin + four wheel clusters, randomised proportions)
and a physically-flavoured pressure field — stagnation pressure ∝ (n̂·v̂)² on
upstream-facing surfaces, suction on the roof/shoulders (curvature proxy),
turbulent wake noise behind the rear axle.  Same split: 700 train / 189 test.

Every sample is ball-tree ordered (core.balltree) and padded to a multiple
of the ball size; features = [xyz, n̂, 1] (in_dim=7).

Variable-size geometries: with ``n_points_range=(lo, hi)`` every sample draws
its own point count (deterministic per index), and ``batches()`` packs the
ragged samples into one padded batch with a per-sample validity mask —
the end-to-end input contract of the batched BSA path (see
docs/architecture.md, "Ragged batching").
"""

from __future__ import annotations

import numpy as np

from repro.core.balltree import (bucket_length, build_balltree_permutation,
                                 pack_items, pad_to_multiple)

N_POINTS = 3586
N_TRAIN, N_TEST = 700, 189


def _superellipsoid(u, v, a, b, c, e1, e2):
    cu, su = np.cos(u), np.sin(u)
    cv, sv = np.cos(v), np.sin(v)
    sgn = lambda x: np.sign(x) * np.abs(x)
    x = a * sgn(cv) * np.abs(cv) ** (e1 - 1) * sgn(cu) * np.abs(cu) ** (e2 - 1)
    y = b * sgn(cv) * np.abs(cv) ** (e1 - 1) * sgn(su) * np.abs(su) ** (e2 - 1)
    z = c * sgn(sv) * np.abs(sv) ** (e1 - 1)
    return np.stack([x, y, z], -1)


def _make_car(rng: np.random.Generator, n: int) -> np.ndarray:
    """n surface points of a car-ish shape, length axis = x, up = z."""
    parts = []
    # body
    nb = int(n * 0.55)
    u = rng.uniform(-np.pi, np.pi, nb)
    v = rng.uniform(-np.pi / 2, np.pi / 2, nb)
    body = _superellipsoid(u, v, a=2.0 + 0.3 * rng.uniform(), b=0.8,
                           c=0.45, e1=0.8, e2=0.9)
    body[:, 2] += 0.5
    parts.append(body)
    # cabin
    nc = int(n * 0.25)
    u = rng.uniform(-np.pi, np.pi, nc)
    v = rng.uniform(0, np.pi / 2, nc)
    cab = _superellipsoid(u, v, a=0.9 + 0.2 * rng.uniform(), b=0.7,
                          c=0.4, e1=0.9, e2=0.9)
    cab[:, 0] -= 0.2
    cab[:, 2] += 0.95
    parts.append(cab)
    # wheels
    nw = n - nb - nc
    per = nw // 4
    got = 0
    for sx in (-1.3, 1.15):
        for sy in (-0.75, 0.75):
            m = per if got < 3 * per else nw - 3 * per
            got += m
            th = rng.uniform(0, 2 * np.pi, m)
            wx = 0.33 * np.cos(th) + sx
            wz = 0.33 * np.sin(th) + 0.33
            wy = sy + rng.uniform(-0.08, 0.08, m)
            parts.append(np.stack([wx, wy, wz], -1))
    pts = np.concatenate(parts)[:n]
    pts += rng.normal(0, 0.005, pts.shape)
    return pts.astype(np.float32)


def _normals(pts: np.ndarray, k: int = 12) -> np.ndarray:
    """Approximate outward normals via local PCA (small n ⇒ exact enough)."""
    center = pts.mean(0)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    idx = np.argpartition(d2, k, axis=1)[:, :k]
    nrm = np.empty_like(pts)
    for i in range(pts.shape[0]):
        nb = pts[idx[i]] - pts[idx[i]].mean(0)
        _, _, vt = np.linalg.svd(nb, full_matrices=False)
        v = vt[-1]
        if np.dot(v, pts[i] - center) < 0:
            v = -v
        nrm[i] = v
    return nrm.astype(np.float32)


def _pressure(pts: np.ndarray, nrm: np.ndarray, rng) -> np.ndarray:
    """Physically-flavoured pressure: stagnation + suction + wake noise."""
    v = np.array([-1.0, 0.0, 0.0], np.float32)          # flow toward −x
    ndv = nrm @ v
    cp = np.where(ndv > 0, ndv ** 2, -0.5 * ndv ** 2)   # stagnation vs suction
    cp -= 0.3 * np.clip(nrm[:, 2], 0, None) ** 2        # roof suction
    wake = (pts[:, 0] < -0.8).astype(np.float32)
    cp += wake * rng.normal(0, 0.08, pts.shape[0])
    cp += 0.02 * rng.normal(0, 1, pts.shape[0])
    return cp.astype(np.float32)[:, None]


class ShapeNetCarDataset:
    """Deterministic synthetic clone.  ``__getitem__`` → dict ready for the
    model: ball-ordered, padded features (N,7), target (N,1), mask (N,).

    ``n_points_range=(lo, hi)`` turns on variable-size clouds: sample i draws
    a point count in [lo, hi] from its own deterministic rng, so the set is
    reproducible but ragged.  ``batches()`` then pads every sample of a batch
    to one shared length (``pad_to`` or the batch max rounded to the ball
    size) with per-sample masks — a packed batch the jitted step consumes
    whole."""

    def __init__(self, split: str = "train", ball_size: int = 256,
                 n_points: int = N_POINTS, seed: int = 1234,
                 normalize: bool = True,
                 n_points_range: tuple[int, int] | None = None):
        assert split in ("train", "test")
        self.split = split
        self.ball_size = ball_size
        self.n_points = n_points
        self.n_points_range = n_points_range
        self.seed = seed
        self.offset = 0 if split == "train" else N_TRAIN
        self.length = N_TRAIN if split == "train" else N_TEST
        self.normalize = normalize

    def __len__(self):
        return self.length

    @property
    def max_padded_len(self) -> int:
        """Upper bound on any sample's padded length — pass as ``pad_to`` to
        ``batches()`` to freeze the batch shape (single jit compilation)."""
        hi = self.n_points_range[1] if self.n_points_range else self.n_points
        return bucket_length(hi, self.ball_size, geometric=False)

    def _sample_n(self, rng: np.random.Generator) -> int:
        if self.n_points_range is None:
            return self.n_points
        lo, hi = self.n_points_range
        return int(rng.integers(lo, hi + 1))

    def __getitem__(self, i: int) -> dict:
        rng = np.random.default_rng(self.seed + self.offset + i)
        pts = _make_car(rng, self._sample_n(rng))
        nrm = _normals(pts)
        p = _pressure(pts, nrm, rng)
        if self.normalize:
            p = (p - 0.02) / 0.25
        perm = build_balltree_permutation(pts, self.ball_size)
        pts, nrm, p = pts[perm], nrm[perm], p[perm]
        feats = np.concatenate([pts, nrm, np.ones((pts.shape[0], 1), np.float32)], -1)
        feats, mask = pad_to_multiple(feats, self.ball_size)
        p, _ = pad_to_multiple(p, self.ball_size)
        return {"feats": feats, "target": p, "mask": mask}

    def batches(self, batch_size: int, *, shuffle=True, seed=0, epochs=None,
                pad_to: int | None = None):
        """Yield packed batches {feats (B,L,7), target (B,L,1), mask (B,L)}.

        L is ``pad_to`` if given (static shapes → one jit compilation), else
        the largest sample length in the batch (already a ball multiple).

        .. deprecated:: ``pad_to=`` bucket padding spends FLOPs on dummy
           rows in every slot shorter than L.  Prefer the packed-varlen
           layout — ``core.balltree.pack_varlen`` + an ``offsets`` batch key
           (or ``GeometryEngine``'s default packed mode); see docs/varlen.md.
        """
        if pad_to is not None:
            import warnings
            warnings.warn(
                "batches(pad_to=...) bucket padding is deprecated; prefer "
                "the packed-varlen layout (core.balltree.pack_varlen + an "
                "'offsets' batch key, or GeometryEngine's packed mode) — "
                "see docs/varlen.md",
                DeprecationWarning, stacklevel=2)
        rng = np.random.default_rng(seed)
        epoch = 0
        while epochs is None or epoch < epochs:
            order = rng.permutation(self.length) if shuffle else np.arange(self.length)
            for s in range(0, self.length - batch_size + 1, batch_size):
                items = [self[int(j)] for j in order[s:s + batch_size]]
                yield pack_items(items, pad_to)
            epoch += 1
