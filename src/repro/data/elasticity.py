"""Synthetic Elasticity benchmark proxy (Li et al. 2021): 972-point meshes of
a plate with a random void, stress field regression.  Same sizes as the
paper's Table 2 setting (seq len 972 → padded to 1024 = 4 balls of 256).

Supports the same ragged-batching contract as ``data/shapenet.py``:
``n_points_range=(lo, hi)`` gives every mesh its own point count and
``batches()`` packs mixed-size meshes into one padded batch + mask.
"""

from __future__ import annotations

import numpy as np

from repro.core.balltree import (bucket_length, build_balltree_permutation,
                                 pack_items, pad_to_multiple)

N_POINTS = 972


class ElasticityDataset:
    def __init__(self, split="train", ball_size: int = 256, seed: int = 77,
                 n_points: int = N_POINTS,
                 n_points_range: tuple[int, int] | None = None):
        self.length = 1000 if split == "train" else 200
        self.offset = 0 if split == "train" else 1000
        self.ball_size = ball_size
        self.seed = seed
        self.n_points = n_points
        self.n_points_range = n_points_range

    def __len__(self):
        return self.length

    @property
    def max_padded_len(self) -> int:
        """Static batch length for ``batches(pad_to=...)`` (see shapenet)."""
        hi = self.n_points_range[1] if self.n_points_range else self.n_points
        return bucket_length(hi, self.ball_size, geometric=False)

    def __getitem__(self, i: int) -> dict:
        rng = np.random.default_rng(self.seed + self.offset + i)
        # unit plate with an elliptic void; points on a jittered grid
        if self.n_points_range is None:
            n = self.n_points
        else:
            n = int(rng.integers(self.n_points_range[0], self.n_points_range[1] + 1))
        pts = rng.uniform(0, 1, (int(n * 1.6), 2)).astype(np.float32)
        cx, cy = rng.uniform(0.3, 0.7, 2)
        rx, ry = rng.uniform(0.08, 0.22, 2)
        keep = (((pts[:, 0] - cx) / rx) ** 2 + ((pts[:, 1] - cy) / ry) ** 2) > 1.0
        pts = pts[keep][:n]
        while pts.shape[0] < n:  # top-up
            extra = rng.uniform(0, 1, (n, 2)).astype(np.float32)
            keep = (((extra[:, 0] - cx) / rx) ** 2 + ((extra[:, 1] - cy) / ry) ** 2) > 1.0
            pts = np.concatenate([pts, extra[keep]])[:n]
        # stress proxy: concentration around the void (Kirsch-like decay)
        d = np.sqrt(((pts[:, 0] - cx) / rx) ** 2 + ((pts[:, 1] - cy) / ry) ** 2)
        stress = (1.0 + 1.5 / np.maximum(d, 1.0) ** 2 *
                  (1.0 + np.cos(2 * np.arctan2(pts[:, 1] - cy, pts[:, 0] - cx))))
        stress = stress.astype(np.float32)[:, None]
        p3 = np.concatenate([pts, np.zeros((n, 1), np.float32)], -1)
        perm = build_balltree_permutation(p3, self.ball_size)
        pts, stress = pts[perm], stress[perm]
        feats = np.concatenate(
            [pts, np.zeros((n, 1), np.float32),
             np.broadcast_to([cx, cy, rx], (n, 3)).astype(np.float32)], -1)
        feats, mask = pad_to_multiple(feats, self.ball_size)
        stress, _ = pad_to_multiple(stress, self.ball_size)
        return {"feats": feats, "target": stress, "mask": mask}

    def batches(self, batch_size: int, *, shuffle=True, seed=0, epochs=None,
                pad_to: int | None = None):
        """Yield packed {feats, target, mask} batches (ragged-safe)."""
        rng = np.random.default_rng(seed)
        epoch = 0
        while epochs is None or epoch < epochs:
            order = rng.permutation(self.length) if shuffle else np.arange(self.length)
            for s in range(0, self.length - batch_size + 1, batch_size):
                items = [self[int(j)] for j in order[s:s + batch_size]]
                yield pack_items(items, pad_to)
            epoch += 1
