"""Fault-tolerant checkpointing: sharded npz + JSON manifest, async writer,
atomic renames, keep-last-k pruning, and RESHARD-ON-RESTORE (elastic
restarts onto a different mesh).

Layout:
  <dir>/step_000100.tmp/          (written, then atomically renamed)
  <dir>/step_000100/
      manifest.json               tree structure, shapes, dtypes, step
      proc00.npz                  this process's addressable shards

On a real multi-host cluster each process saves only its addressable shards
(`jax.experimental.multihost_utils` barrier before rename); this container
is single-process so proc00 holds everything — the layout and restore path
are identical.  Restore takes target shardings and `device_put`s each leaf,
which is exactly the elastic re-shard: save on mesh A, restore on mesh B.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def latest_step(ckpt_dir) -> int | None:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.iterdir()
             if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, ckpt_dir, *, keep_last: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self.save_count = 0

    # ---------------- save ----------------

    def save(self, step: int, state, *, extra: dict | None = None,
             block: bool = False):
        """Snapshot ``state`` (pytree of jax/np arrays) at ``step``."""
        self.wait()  # double-buffer: never two in-flight writes
        flat, _ = _flatten(state)
        # materialise on host NOW (cheap np views) so training can proceed
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = {
            "step": int(step),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "time": time.time(),
        }
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "proc00.npz", **host)
        (tmp / "manifest.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)            # atomic publish
        self.save_count += 1
        self._prune()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore ----------------

    def restore(self, template, *, step: int | None = None, shardings=None):
        """Restore into the structure of ``template``.  ``shardings`` (same
        pytree structure, optional) re-shards on load — elastic restart."""
        if step is None:
            step = latest_step(self.dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "proc00.npz")
        flat_t, treedef = _flatten(template)
        flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
        leaves = []
        for key in flat_t:
            arr = data[key]
            want = flat_t[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(f"{key}: ckpt {arr.shape} != template {want.shape}")
            arr = arr.astype(want.dtype)
            if key in flat_s:
                arr = jax.device_put(arr, flat_s[key])
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        return state, meta
