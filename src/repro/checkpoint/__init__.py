from repro.checkpoint.manager import CheckpointManager, latest_step  # noqa: F401
