"""Blocked local causal attention Pallas kernel (the LM 'ball' branch).

Query block i attends causally within block i and fully to block i−1 —
the TPU-aligned blocked equivalent of a sliding window.  The previous block
is fetched by passing K (and V) twice with two index maps (self / prev),
so one grid step holds a (w, D) query tile and a (2w, D) key tile in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEG_INF, should_interpret

__all__ = ["local_window_kernel_call"]


def _kernel(q_ref, ks_ref, vs_ref, kp_ref, vp_ref, o_ref, *, scale: float, w: int):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                       # (w, D)
    k = jnp.concatenate([kp_ref[0], ks_ref[0]], axis=0).astype(jnp.float32)  # (2w, D)
    v = jnp.concatenate([vp_ref[0], vs_ref[0]], axis=0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qi = jax.lax.broadcasted_iota(jnp.int32, (w, 2 * w), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (w, 2 * w), 1)
    ok = ki <= qi + w                                      # prev full + self causal
    ok = ok & ((i > 0) | (ki >= w))                        # block 0 has no prev
    s = jnp.where(ok, s, NEG_INF)
    mx = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(s - mx)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    o = jax.lax.dot_general((p / denom).astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def local_window_kernel_call(q, k, v, *, window: int, interpret: bool | None = None):
    """q,k,v: (BH, N, D).  Returns (BH, N, D)."""
    BH, N, D = q.shape
    w = window
    assert N % w == 0
    if interpret is None:
        interpret = should_interpret()
    grid = (BH, N // w)
    self_blk = pl.BlockSpec((1, w, D), lambda b, i: (b, i, 0))
    prev_blk = pl.BlockSpec((1, w, D), lambda b, i: (b, jnp.maximum(i - 1, 0), 0))
    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (D ** 0.5), w=w),
        grid=grid,
        in_specs=[self_blk, self_blk, self_blk, prev_blk, prev_blk],
        out_specs=self_blk,
        out_shape=jax.ShapeDtypeStruct((BH, N, D), q.dtype),
        interpret=interpret,
    )(q, k, v, k, v)
