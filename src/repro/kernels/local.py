"""Blocked local causal attention Pallas kernel (the LM 'ball' branch).

Query block i attends causally within block i and fully to block i−1 —
the TPU-aligned blocked equivalent of a sliding window.  The previous block
is fetched by passing K (and V) twice with two index maps (self / prev).

GQA-NATIVE: the grid iterates KV heads.  Queries arrive as
(B·Hkv, rep, N, D); one grid step holds the group's fused (rep·w, D) query
tile and the (w, D) self / prev key tiles in VMEM — the K/V fetch is shared
by all ``rep`` query heads of the GQA group instead of being duplicated per
head.

Key-validity masking for ragged batches rides the same fetch pattern: the
per-token additive bias row (B, N) fp32 (0 valid / NEG_INF padding) is
passed twice with the self / prev index maps and added in LOGIT space before
the softmax — identical semantics to the bta/flash kernels, so a packed
batch of mixed-size sequences is one grid launch.

TILE-OCCUPANCY SKIPPING at HALF-TILE granularity (``kernels/occupancy.py``):
per-block any-valid-key verdicts (B, n_b) int32 ride in as a SCALAR-PREFETCH
operand.  The forward streams the prev half and the self half as two
separately ``pl.when``-guarded softmax steps over shared m/l/acc scratch —
a block whose prev neighbour is all-masked (or absent: block 0 / a packed
sample boundary) computes only the self half; a block whose own keys are
all masked skips that half too.  A fully dead block finalizes the zeroed
scratch to zeros with lse = LSE_EMPTY — exactly the jnp oracle's
all-masked-row output, so skipping is bit-exact.  The backward guards its
three contributions the same way (prev→dQ, self→dQ+dK/dV, next→dK/dV).

PRECISION CONTRACT (``common.resolve_compute_dtype``): operand tiles cast
to the compute dtype (fp32 in → fp32, bf16 in → bf16 through QK^T and PV,
fp8 for QK^T operands under REPRO_FP8=1) while every ``dot_general``
accumulates fp32 and softmax statistics stay fp32.

Differentiable: forward also emits per-row logsumexp (B·Hkv, rep, N).  The
backward is a single-pass per-block kernel — dQ of block i needs K/V of
blocks {i−1, i} (already the forward fetch pattern), while dK/dV of block i
get contributions from query blocks {i, i+1}; the NEXT query block (with its
dO/lse/delta rows) is fetched via a second set of index maps, so each grid
cell owns its output blocks outright and no cross-cell accumulation is
needed.  dK/dV sum over the group's rep query heads inside the
(rep·w)-row contractions.  The key bias enters the recomputed logits of both
contributions, so masked keys get exactly zero gradient.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, interpret_batch_map, lse_finalize,
                                  mma_dtype, p_from_lse, resolve_compute_dtype,
                                  should_interpret)
from repro.kernels.occupancy import key_tile_live

__all__ = ["local_window_kernel_call"]


def _causal_mask(s, *, rows, w):
    """Within-block causal mask for one (rep·w, w) self-half tile.  Row r is
    query position r % w (rep-major layout), so every GQA head of the group
    shares one mask row."""
    qi = jax.lax.broadcasted_iota(jnp.int32, (rows, w), 0) % w
    ki = jax.lax.broadcasted_iota(jnp.int32, (rows, w), 1)
    return jnp.where(ki <= qi, s, NEG_INF)


def _fwd_kernel(kvl_ref, q_ref, ks_ref, vs_ref, kp_ref, vp_ref, bs_ref, bp_ref,
                ss_ref, sp_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                scale: float, w: int, nh: int, compute: str):
    b = pl.program_id(0)
    i = pl.program_id(1)
    rep, _, D = q_ref.shape[1:]
    rows = rep * w
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))
    sb = b // nh
    live_self = kvl_ref[sb, i] != 0
    live_prev = ((i > 0) & (sp_ref[0, 0] == ss_ref[0, 0])
                 & (kvl_ref[sb, jnp.maximum(i - 1, 0)] != 0))

    # one visit per grid cell — init unconditionally, halves merge into it
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(sdt).reshape(rows, D)              # (rep·w, D)

    def _half(k_half, v_half, bias_half, self_half):
        s = jax.lax.dot_general(q, k_half.astype(sdt), (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + bias_half
        if self_half:
            s = _causal_mask(s, rows=rows, w=w)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        m_scr[...] = m_new
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(adt), v_half.astype(adt), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(live_prev)
    def _prev_half():
        _half(kp_ref[0], vp_ref[0], bp_ref[0], self_half=False)

    @pl.when(live_self)
    def _self_half():
        _half(ks_ref[0], vs_ref[0], bs_ref[0], self_half=True)

    denom = jnp.maximum(l_scr[...], 1e-20)                 # dead block → zeros
    o_ref[0] = (acc_scr[...] / denom).reshape(rep, w, D).astype(o_ref.dtype)
    m_safe_f = jnp.maximum(m_scr[...], NEG_INF / 2)
    lse_ref[0] = lse_finalize(m_safe_f, l_scr[...])[:, 0].reshape(rep, w)


def _bwd_kernel(kvl_ref, qs_ref, qn_ref, ks_ref, kp_ref, vs_ref, vp_ref,
                bs_ref, bp_ref, ss_ref, sp_ref, sn_ref,
                dos_ref, don_ref, lses_ref, lsen_ref, dels_ref, deln_ref,
                dq_ref, dk_ref, dv_ref, dq_scr, dk_scr, dv_scr, *,
                scale: float, w: int, n_b: int, nh: int, compute: str):
    b = pl.program_id(0)
    i = pl.program_id(1)
    rep, _, D = qs_ref.shape[1:]
    rows = rep * w
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))
    sb = b // nh
    live_self = kvl_ref[sb, i] != 0                        # my keys carry weight
    live_prev = ((i > 0) & (sp_ref[0, 0] == ss_ref[0, 0])
                 & (kvl_ref[sb, jnp.maximum(i - 1, 0)] != 0))
    # next block's queries contribute to MY dK/dV iff my keys are valid and a
    # real same-sample next block exists
    live_next = (i < n_b - 1) & (sn_ref[0, 0] == ss_ref[0, 0]) & live_self

    dq_scr[...] = jnp.zeros_like(dq_scr)
    dk_scr[...] = jnp.zeros_like(dk_scr)
    dv_scr[...] = jnp.zeros_like(dv_scr)

    qs = qs_ref[0].astype(sdt).reshape(rows, D)            # (rep·w, D)
    dos = dos_ref[0].astype(adt).reshape(rows, D)
    lses = lses_ref[0].reshape(rows, 1)
    dels = dels_ref[0].reshape(rows, 1)

    @pl.when(live_prev)
    def _prev_half():                                      # prev keys → my dQ
        kp = kp_ref[0]
        s = jax.lax.dot_general(qs, kp.astype(sdt), (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + bp_ref[0]                                  # prev half: fully visible
        p = p_from_lse(s, lses)                            # (rep·w, w)
        dp = jax.lax.dot_general(dos, vp_ref[0].astype(adt),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dels) * scale
        dq_scr[...] += jax.lax.dot_general(ds.astype(adt), kp.astype(adt),
                                           (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(live_self)
    def _self_half():                                      # my keys → dQ, dK, dV
        ks = ks_ref[0]
        vs = vs_ref[0].astype(adt)
        s = jax.lax.dot_general(qs, ks.astype(sdt), (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + bs_ref[0]
        s = _causal_mask(s, rows=rows, w=w)
        p = p_from_lse(s, lses)                            # (rep·w, w)
        dp = jax.lax.dot_general(dos, vs, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dels) * scale
        dq_scr[...] += jax.lax.dot_general(ds.astype(adt), ks.astype(adt),
                                           (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        # the (0,)-axis contraction sums the group's rep·w rows
        dv_scr[...] += jax.lax.dot_general(p.astype(adt), dos,
                                           (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(adt), qs_ref[0].astype(adt).reshape(rows, D),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(live_next)
    def _next_part():                                      # next queries → my dK/dV
        qn = qn_ref[0].astype(sdt).reshape(rows, D)
        don = don_ref[0].astype(adt).reshape(rows, D)
        # query block i+1 sees block i as its fully-visible prev half; its
        # logits here were part of its forward softmax, so exp(sn − lse) ≤ 1
        sn = jax.lax.dot_general(qn, ks_ref[0].astype(sdt),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        sn = sn + bs_ref[0]
        pn = p_from_lse(sn, lsen_ref[0].reshape(rows, 1))  # (rep·w, w)
        dv_scr[...] += jax.lax.dot_general(pn.astype(adt), don,
                                           (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dpn = jax.lax.dot_general(don, vs_ref[0].astype(adt),
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        dsn = pn * (dpn - deln_ref[0].reshape(rows, 1)) * scale
        dk_scr[...] += jax.lax.dot_general(
            dsn.astype(adt), qn_ref[0].astype(adt).reshape(rows, D),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    dq_ref[0] = dq_scr[...].reshape(rep, w, D).astype(dq_ref.dtype)
    dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
    dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _fwd_call(q, k, v, key_bias, blk_seg, kv_live, *, window, n_heads,
              interpret, compute):
    BH, rep, N, D = q.shape
    w = window
    H = n_heads                                            # KV heads
    assert N % w == 0
    n_b = N // w
    q_blk = pl.BlockSpec((1, rep, w, D), lambda b, i, lv: (b, 0, i, 0))
    self_blk = pl.BlockSpec((1, w, D), lambda b, i, lv: (b, i, 0))
    prev_blk = pl.BlockSpec((1, w, D),
                            lambda b, i, lv: (b, jnp.maximum(i - 1, 0), 0))
    bias_self = pl.BlockSpec((1, w), lambda b, i, lv: (b // H, i))
    bias_prev = pl.BlockSpec((1, w),
                             lambda b, i, lv: (b // H, jnp.maximum(i - 1, 0)))
    seg_self = pl.BlockSpec((1, 1), lambda b, i, lv: (b // H, i))
    seg_prev = pl.BlockSpec((1, 1),
                            lambda b, i, lv: (b // H, jnp.maximum(i - 1, 0)))
    lse_blk = pl.BlockSpec((1, rep, w), lambda b, i, lv: (b, 0, i))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_b),
        in_specs=[q_blk, self_blk, self_blk, prev_blk, prev_blk,
                  bias_self, bias_prev, seg_self, seg_prev],
        out_specs=(q_blk, lse_blk),
        scratch_shapes=[
            pltpu.VMEM((rep * w, 1), jnp.float32),
            pltpu.VMEM((rep * w, 1), jnp.float32),
            pltpu.VMEM((rep * w, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=1.0 / (D ** 0.5), w=w, nh=H,
                          compute=compute),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, rep, N), jnp.float32)),
        interpret=interpret,
    )(kv_live, q, k, v, k, v, key_bias, key_bias, blk_seg, blk_seg)


def _bwd_call(q, k, v, key_bias, blk_seg, kv_live, do, lse, delta, *, window,
              n_heads, interpret, compute):
    BH, rep, N, D = q.shape
    w = window
    H = n_heads
    n_b = N // w
    q_self = pl.BlockSpec((1, rep, w, D), lambda b, i, lv: (b, 0, i, 0))
    q_next = pl.BlockSpec((1, rep, w, D),
                          lambda b, i, lv: (b, 0, jnp.minimum(i + 1, n_b - 1), 0))
    self_blk = pl.BlockSpec((1, w, D), lambda b, i, lv: (b, i, 0))
    prev_blk = pl.BlockSpec((1, w, D),
                            lambda b, i, lv: (b, jnp.maximum(i - 1, 0), 0))
    bias_self = pl.BlockSpec((1, w), lambda b, i, lv: (b // H, i))
    bias_prev = pl.BlockSpec((1, w),
                             lambda b, i, lv: (b // H, jnp.maximum(i - 1, 0)))
    seg_self = pl.BlockSpec((1, 1), lambda b, i, lv: (b // H, i))
    seg_prev = pl.BlockSpec((1, 1),
                            lambda b, i, lv: (b // H, jnp.maximum(i - 1, 0)))
    seg_next = pl.BlockSpec((1, 1),
                            lambda b, i, lv: (b // H, jnp.minimum(i + 1, n_b - 1)))
    row_self = pl.BlockSpec((1, rep, w), lambda b, i, lv: (b, 0, i))
    row_next = pl.BlockSpec((1, rep, w),
                            lambda b, i, lv: (b, 0, jnp.minimum(i + 1, n_b - 1)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_b),
        in_specs=[q_self, q_next,                # q self / next
                  self_blk, prev_blk,            # k self / prev
                  self_blk, prev_blk,            # v self / prev
                  bias_self, bias_prev,          # key bias self / prev
                  seg_self, seg_prev, seg_next,  # block segment ids
                  q_self, q_next,                # do self / next
                  row_self, row_next,            # lse self / next
                  row_self, row_next],           # delta self / next
        out_specs=(q_self, self_blk, self_blk),
        scratch_shapes=[
            pltpu.VMEM((rep * w, D), jnp.float32),
            pltpu.VMEM((w, D), jnp.float32),
            pltpu.VMEM((w, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=1.0 / (D ** 0.5), w=w, n_b=n_b,
                          nh=H, compute=compute),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, N, D), k.dtype),
                   jax.ShapeDtypeStruct((BH, N, D), v.dtype)),
        interpret=interpret,
    )(kv_live, q, q, k, k, v, v, key_bias, key_bias, blk_seg, blk_seg, blk_seg,
      do, do, lse, lse, delta, delta)


@functools.lru_cache(maxsize=None)
def _make_vjp(window: int, n_heads: int, interpret: bool, compute: str):
    kw = dict(window=window, n_heads=n_heads, interpret=interpret,
              compute=compute)

    @jax.custom_vjp
    def attend(q, k, v, key_bias, blk_seg, kv_live):
        return _fwd_call(q, k, v, key_bias, blk_seg, kv_live, **kw)[0]

    def attend_fwd(q, k, v, key_bias, blk_seg, kv_live):
        o, lse = _fwd_call(q, k, v, key_bias, blk_seg, kv_live, **kw)
        return o, (q, k, v, key_bias, blk_seg, kv_live, o, lse)

    def attend_bwd(res, do):
        q, k, v, key_bias, blk_seg, kv_live, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        dq, dk, dv = _bwd_call(q, k, v, key_bias, blk_seg, kv_live, do, lse,
                               delta, **kw)
        return dq, dk, dv, None, None, None                # bias/seg/live: no grad

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


@functools.partial(jax.jit, static_argnames=("window", "n_heads", "interpret",
                                             "compute"))
def local_window_kernel_call(q, k, v, key_bias, *, window: int, n_heads: int,
                             interpret: bool | None = None, blk_seg=None,
                             compute: str | None = None):
    """q: (B·Hkv, rep, N, D) grouped queries; k, v: (B·Hkv, N, D) — one K/V
    stream per KV head shared by its rep query heads; key_bias: (B, N) fp32
    additive (0 valid / NEG_INF padding); ``n_heads`` is the KV head count.
    ``blk_seg``: optional (B, N/window) int32 per-block segment ids for
    PACKED-VARLEN batches — a block never attends a prev block of a
    different segment, and its keys get no gradient from a next block of a
    different segment (None = one segment, the dense behaviour).
    ``compute``: canonical matmul-operand dtype name (None resolves from
    q.dtype).  Per-block key liveness is derived from ``key_bias`` and
    scalar-prefetched: all-masked self / prev halves are skipped exactly.
    Returns (B·Hkv, rep, N, D).
    Differentiable in q, k, v (bias and segment ids carry no gradient)."""
    if interpret is None:
        interpret = should_interpret()
    if compute is None:
        compute = resolve_compute_dtype(q.dtype)
    if blk_seg is None:
        blk_seg = jnp.zeros((key_bias.shape[0], q.shape[2] // window),
                            jnp.int32)
    kv_live = key_tile_live(key_bias, window).astype(jnp.int32)  # (B, n_b)
    if interpret and q.shape[0] > 1:
        # CPU fallback: per-slice grids keep the interpreter linear in B·Hkv
        bias_bh = jnp.repeat(key_bias, n_heads, axis=0)
        seg_bh = jnp.repeat(blk_seg, n_heads, axis=0)
        live_bh = jnp.repeat(kv_live, n_heads, axis=0)
        return interpret_batch_map(_make_vjp(window, 1, True, compute),
                                   q, k, v, bias_bh, seg_bh, live_bh)
    return _make_vjp(window, n_heads, interpret, compute)(
        q, k, v, key_bias, blk_seg, kv_live)
