"""Blocked local causal attention Pallas kernel (the LM 'ball' branch).

Query block i attends causally within block i and fully to block i−1 —
the TPU-aligned blocked equivalent of a sliding window.  The previous block
is fetched by passing K (and V) twice with two index maps (self / prev).

GQA-NATIVE: the grid iterates KV heads.  Queries arrive as
(B·Hkv, rep, N, D); one grid step holds the group's fused (rep·w, D) query
tile and a single (2w, D) key tile in VMEM — the K/V fetch is shared by all
``rep`` query heads of the GQA group instead of being duplicated per head.

Key-validity masking for ragged batches rides the same fetch pattern: the
per-token additive bias row (B, N) fp32 (0 valid / NEG_INF padding) is
passed twice with the self / prev index maps and added in LOGIT space before
the softmax — identical semantics to the bta/flash kernels, so a packed
batch of mixed-size sequences is one grid launch.

Differentiable: forward also emits per-row logsumexp (B·Hkv, rep, N).  The
backward is a single-pass per-block kernel — dQ of block i needs K/V of
blocks {i−1, i} (already the forward fetch pattern), while dK/dV of block i
get contributions from query blocks {i, i+1}; the NEXT query block (with its
dO/lse/delta rows) is fetched via a second set of index maps, so each grid
cell owns its output blocks outright and no cross-cell accumulation is
needed.  dK/dV sum over the group's rep query heads inside the
(rep·w)-row contractions.  The key bias enters the recomputed logits of both
contributions, so masked keys get exactly zero gradient.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (NEG_INF, interpret_batch_map, lse_finalize,
                                  p_from_lse, should_interpret)

__all__ = ["local_window_kernel_call"]


def _window_mask(s, i, *, rows, w, same_prev):
    """Causal-within-self + full-prev mask for the fused (rep·w, 2w) tile.

    Row r is query position r % w of the block (rep-major layout), so every
    GQA head of the group shares one mask row.  ``same_prev`` (traced scalar
    bool) is False when the previous block belongs to a DIFFERENT packed
    sample — the varlen boundary case — which hides the prev half entirely,
    exactly like block 0 (dense batches pass all-equal segment ids, so it is
    always True there)."""
    qi = jax.lax.broadcasted_iota(jnp.int32, (rows, 2 * w), 0) % w
    ki = jax.lax.broadcasted_iota(jnp.int32, (rows, 2 * w), 1)
    ok = ki <= qi + w                                      # prev full + self causal
    ok = ok & (((i > 0) & same_prev) | (ki >= w))          # no prev: block 0 /
    return jnp.where(ok, s, NEG_INF)                       # sample boundary


def _fwd_kernel(q_ref, ks_ref, vs_ref, kp_ref, vp_ref, bs_ref, bp_ref,
                ss_ref, sp_ref, o_ref, lse_ref, *, scale: float, w: int):
    i = pl.program_id(1)
    rep, _, D = q_ref.shape[1:]
    rows = rep * w
    q = q_ref[0].astype(jnp.float32).reshape(rows, D)      # (rep·w, D)
    k = jnp.concatenate([kp_ref[0], ks_ref[0]], axis=0).astype(jnp.float32)  # (2w, D)
    v = jnp.concatenate([vp_ref[0], vs_ref[0]], axis=0)
    bias = jnp.concatenate([bp_ref[0], bs_ref[0]], axis=0)  # (2w,) key validity
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + bias
    s = _window_mask(s, i, rows=rows, w=w,
                     same_prev=sp_ref[0, 0] == ss_ref[0, 0])
    mx = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(s - mx)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.maximum(l, 1e-20)
    o = jax.lax.dot_general((p / denom).astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.reshape(rep, w, D).astype(o_ref.dtype)
    lse_ref[0] = lse_finalize(mx, l)[:, 0].reshape(rep, w)


def _bwd_kernel(qs_ref, qn_ref, ks_ref, kp_ref, vs_ref, vp_ref, bs_ref, bp_ref,
                ss_ref, sp_ref, sn_ref,
                dos_ref, don_ref, lses_ref, lsen_ref, dels_ref, deln_ref,
                dq_ref, dk_ref, dv_ref, *, scale: float, w: int, n_b: int):
    i = pl.program_id(1)
    rep, _, D = qs_ref.shape[1:]
    rows = rep * w
    qs = qs_ref[0].astype(jnp.float32).reshape(rows, D)    # (rep·w, D)
    ks = ks_ref[0].astype(jnp.float32)
    vs = vs_ref[0].astype(jnp.float32)
    dos = dos_ref[0].astype(jnp.float32).reshape(rows, D)
    kcat = jnp.concatenate([kp_ref[0], ks_ref[0]], axis=0).astype(jnp.float32)
    vcat = jnp.concatenate([vp_ref[0], vs_ref[0]], axis=0).astype(jnp.float32)
    bcat = jnp.concatenate([bp_ref[0], bs_ref[0]], axis=0)  # (2w,)

    # --- dQ of block i (keys = prev ‖ self, forward mask + key bias) ---
    s = jax.lax.dot_general(qs, kcat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + bcat
    s = _window_mask(s, i, rows=rows, w=w,
                     same_prev=sp_ref[0, 0] == ss_ref[0, 0])
    p = p_from_lse(s, lses_ref[0].reshape(rows, 1))        # (rep·w, 2w)
    dp = jax.lax.dot_general(dos, vcat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dels_ref[0].reshape(rows, 1)) * scale
    dq = jax.lax.dot_general(ds, kcat, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dq_ref[0] = dq.reshape(rep, w, D).astype(dq_ref.dtype)

    # --- dK/dV of block i, self part (query block i, columns w:) — the
    #     (0,)-axis contraction sums the group's rep·w rows ---
    p_self = p[:, w:]
    ds_self = ds[:, w:]
    dv = jax.lax.dot_general(p_self, dos, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dk = jax.lax.dot_general(ds_self, qs, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # --- dK/dV of block i, next part (query block i+1 sees block i as its
    #     fully-visible prev; zeroed for the last block where no next exists) ---
    qn = qn_ref[0].astype(jnp.float32).reshape(rows, D)
    don = don_ref[0].astype(jnp.float32).reshape(rows, D)
    sn = jax.lax.dot_general(qn, ks, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * scale
    sn = sn + bs_ref[0]
    # kill the clamped self-fetch in LOGIT space when no real next block
    # exists (last block, or the next block starts a different packed
    # sample): its anti-causal logits can exceed lse, and exp-then-zero
    # would give inf·0
    sn = jnp.where((i < n_b - 1) & (sn_ref[0, 0] == ss_ref[0, 0]),
                   sn, NEG_INF)
    pn = p_from_lse(sn, lsen_ref[0].reshape(rows, 1))      # (rep·w, w)
    dv = dv + jax.lax.dot_general(pn, don, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    dpn = jax.lax.dot_general(don, vs, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dsn = pn * (dpn - deln_ref[0].reshape(rows, 1)) * scale
    dk = dk + jax.lax.dot_general(dsn, qn, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _fwd_call(q, k, v, key_bias, blk_seg, *, window, n_heads, interpret):
    BH, rep, N, D = q.shape
    w = window
    H = n_heads                                            # KV heads
    assert N % w == 0
    n_b = N // w
    q_blk = pl.BlockSpec((1, rep, w, D), lambda b, i: (b, 0, i, 0))
    self_blk = pl.BlockSpec((1, w, D), lambda b, i: (b, i, 0))
    prev_blk = pl.BlockSpec((1, w, D), lambda b, i: (b, jnp.maximum(i - 1, 0), 0))
    bias_self = pl.BlockSpec((1, w), lambda b, i: (b // H, i))
    bias_prev = pl.BlockSpec((1, w), lambda b, i: (b // H, jnp.maximum(i - 1, 0)))
    seg_self = pl.BlockSpec((1, 1), lambda b, i: (b // H, i))
    seg_prev = pl.BlockSpec((1, 1), lambda b, i: (b // H, jnp.maximum(i - 1, 0)))
    lse_blk = pl.BlockSpec((1, rep, w), lambda b, i: (b, 0, i))
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=1.0 / (D ** 0.5), w=w),
        grid=(BH, n_b),
        in_specs=[q_blk, self_blk, self_blk, prev_blk, prev_blk,
                  bias_self, bias_prev, seg_self, seg_prev],
        out_specs=(q_blk, lse_blk),
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, rep, N), jnp.float32)),
        interpret=interpret,
    )(q, k, v, k, v, key_bias, key_bias, blk_seg, blk_seg)


def _bwd_call(q, k, v, key_bias, blk_seg, do, lse, delta, *, window, n_heads,
              interpret):
    BH, rep, N, D = q.shape
    w = window
    H = n_heads
    n_b = N // w
    q_self = pl.BlockSpec((1, rep, w, D), lambda b, i: (b, 0, i, 0))
    q_next = pl.BlockSpec((1, rep, w, D),
                          lambda b, i: (b, 0, jnp.minimum(i + 1, n_b - 1), 0))
    self_blk = pl.BlockSpec((1, w, D), lambda b, i: (b, i, 0))
    prev_blk = pl.BlockSpec((1, w, D), lambda b, i: (b, jnp.maximum(i - 1, 0), 0))
    bias_self = pl.BlockSpec((1, w), lambda b, i: (b // H, i))
    bias_prev = pl.BlockSpec((1, w), lambda b, i: (b // H, jnp.maximum(i - 1, 0)))
    seg_self = pl.BlockSpec((1, 1), lambda b, i: (b // H, i))
    seg_prev = pl.BlockSpec((1, 1), lambda b, i: (b // H, jnp.maximum(i - 1, 0)))
    seg_next = pl.BlockSpec((1, 1),
                            lambda b, i: (b // H, jnp.minimum(i + 1, n_b - 1)))
    row_self = pl.BlockSpec((1, rep, w), lambda b, i: (b, 0, i))
    row_next = pl.BlockSpec((1, rep, w),
                            lambda b, i: (b, 0, jnp.minimum(i + 1, n_b - 1)))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=1.0 / (D ** 0.5), w=w, n_b=n_b),
        grid=(BH, n_b),
        in_specs=[q_self, q_next,                # q self / next
                  self_blk, prev_blk,            # k self / prev
                  self_blk, prev_blk,            # v self / prev
                  bias_self, bias_prev,          # key bias self / prev
                  seg_self, seg_prev, seg_next,  # block segment ids
                  q_self, q_next,                # do self / next
                  row_self, row_next,            # lse self / next
                  row_self, row_next],           # delta self / next
        out_specs=(q_self, self_blk, self_blk),
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, N, D), k.dtype),
                   jax.ShapeDtypeStruct((BH, N, D), v.dtype)),
        interpret=interpret,
    )(q, q, k, k, v, v, key_bias, key_bias, blk_seg, blk_seg, blk_seg,
      do, do, lse, lse, delta, delta)


@functools.lru_cache(maxsize=None)
def _make_vjp(window: int, n_heads: int, interpret: bool):
    kw = dict(window=window, n_heads=n_heads, interpret=interpret)

    @jax.custom_vjp
    def attend(q, k, v, key_bias, blk_seg):
        return _fwd_call(q, k, v, key_bias, blk_seg, **kw)[0]

    def attend_fwd(q, k, v, key_bias, blk_seg):
        o, lse = _fwd_call(q, k, v, key_bias, blk_seg, **kw)
        return o, (q, k, v, key_bias, blk_seg, o, lse)

    def attend_bwd(res, do):
        q, k, v, key_bias, blk_seg, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        dq, dk, dv = _bwd_call(q, k, v, key_bias, blk_seg, do, lse, delta, **kw)
        return dq, dk, dv, None, None                      # bias/seg: no grad

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


@functools.partial(jax.jit, static_argnames=("window", "n_heads", "interpret"))
def local_window_kernel_call(q, k, v, key_bias, *, window: int, n_heads: int,
                             interpret: bool | None = None, blk_seg=None):
    """q: (B·Hkv, rep, N, D) grouped queries; k, v: (B·Hkv, N, D) — one K/V
    stream per KV head shared by its rep query heads; key_bias: (B, N) fp32
    additive (0 valid / NEG_INF padding); ``n_heads`` is the KV head count.
    ``blk_seg``: optional (B, N/window) int32 per-block segment ids for
    PACKED-VARLEN batches — a block never attends a prev block of a
    different segment, and its keys get no gradient from a next block of a
    different segment (None = one segment, the dense behaviour).
    Returns (B·Hkv, rep, N, D).
    Differentiable in q, k, v (bias and segment ids carry no gradient)."""
    if interpret is None:
        interpret = should_interpret()
    if blk_seg is None:
        blk_seg = jnp.zeros((key_bias.shape[0], q.shape[2] // window),
                            jnp.int32)
    if interpret and q.shape[0] > 1:
        # CPU fallback: per-slice grids keep the interpreter linear in B·Hkv
        bias_bh = jnp.repeat(key_bias, n_heads, axis=0)
        seg_bh = jnp.repeat(blk_seg, n_heads, axis=0)
        return interpret_batch_map(_make_vjp(window, 1, True),
                                   q, k, v, bias_bh, seg_bh)
    return _make_vjp(window, n_heads, interpret)(q, k, v, key_bias, blk_seg)
