"""Pure-jnp oracles for every Pallas kernel (tests assert allclose).

These re-export / adapt the reference implementations living in
``repro.core`` so each kernel has exactly one oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.branches import NEG_INF, mask_to_bias, sdpa
from repro.core.bsa import ball_attention_ref  # noqa: F401
from repro.core.nsa_causal import local_window_attention_ref  # noqa: F401

__all__ = ["ball_attention_ref", "local_window_attention_ref",
           "flash_attention_ref", "selection_attention_ref"]


def flash_attention_ref(q, k, v, *, key_valid=None, causal=False,
                        block_causal=False, ell=1, bias=None):
    """Oracle for ops.flash_attention.  q:(B,N,H,D), k,v:(B,L,H,D)."""
    B, N, H, D = q.shape
    L = k.shape[1]
    b = jnp.zeros((B, 1, 1, L), jnp.float32)
    if key_valid is not None:
        b = b + mask_to_bias(key_valid[:, None, None, :])
    if bias is not None:
        b = b + bias.reshape(B, 1, 1, L).astype(jnp.float32)
    if causal:
        qi = jnp.arange(N)[:, None]
        ki = jnp.arange(L)[None, :]
        b = b + mask_to_bias((ki <= qi)[None, None])
    if block_causal:
        t = jnp.arange(N)[:, None]
        end = (jnp.arange(L)[None, :] + 1) * ell - 1
        b = b + mask_to_bias((end < t)[None, None])
    out = sdpa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
               v.transpose(0, 2, 1, 3), b)
    return out.transpose(0, 2, 1, 3)


def selection_attention_ref(q, k, v, top_idx, sel_valid, mask, *,
                            block_size: int, group_size: int):
    """Oracle for ops.selection_attention (mirrors core's gather math,
    including the dead-group invalidation: all-padded query groups attend
    nothing and emit exact zeros, like the kernel's skipped tiles)."""
    from repro.kernels.occupancy import invalidate_dead_groups
    sel_valid = invalidate_dead_groups(sel_valid, mask, q.shape[1])
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = block_size
    nb = N // ell
    G = top_idx.shape[1]
    g = N // G
    kb = k.reshape(B, nb, ell, Hkv, D)
    vb = v.reshape(B, nb, ell, Hkv, D)
    bidx = jnp.arange(B)[:, None, None, None]
    safe_idx = jnp.where(sel_valid, top_idx, 0)
    kg = kb[bidx, safe_idx, :, jnp.arange(Hkv)[None, None, :, None], :]
    vg = vb[bidx, safe_idx, :, jnp.arange(Hkv)[None, None, :, None], :]
    L = top_idx.shape[-1] * ell
    kg = kg.reshape(B, G, Hkv, L, D)
    vg = vg.reshape(B, G, Hkv, L, D)
    key_valid = jnp.broadcast_to(sel_valid[..., None],
                                 (B, G, Hkv, top_idx.shape[-1], ell))
    if mask is not None:
        tok_valid = mask.reshape(B, nb, ell)
        tv = tok_valid[jnp.arange(B)[:, None, None, None], safe_idx]
        key_valid = key_valid & tv
    bias = mask_to_bias(key_valid.reshape(B, G, Hkv, 1, 1, L))
    qg = q.reshape(B, G, g, Hkv, rep, D).transpose(0, 1, 3, 4, 2, 5)
    logits = jnp.einsum("bgkrmd,bgkld->bgkrml", qg, kg,
                        preferred_element_type=jnp.float32) / (D ** 0.5)
    logits = logits + bias
    mx = jnp.maximum(logits.max(-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - mx)
    p = jnp.where(logits <= NEG_INF / 2, 0.0, p)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    out = jnp.einsum("bgkrml,bgkld->bgkrmd", p.astype(v.dtype), vg,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return out.transpose(0, 1, 4, 2, 3, 5).reshape(B, N, Hq, D)
