"""Packed-varlen flash-attention Pallas kernel (the cu_seqlens idiom).

The bucket-padded layout gives every sample a full (B, L) slot, so small
samples burn padding FLOPs.  Here all samples share ONE packed axis of
length T = Σ paddedᵢ and an ``offsets`` array marks the boundaries — the
layout NSA-style varlen kernels use (flash-linear-attention's
``USE_OFFSETS`` path): per-sample start/end are resolved *inside* the
kernel, so one compiled shape serves any size mix and total work scales
with the real token count.

Two mechanisms enforce sample isolation:

  * **Within-tile segment masking** — per-position int32 segment ids
    (``numerics.segment_ids_from_offsets``) for queries and keys ride in as
    tensor operands; a tile that straddles a sample boundary masks the
    cross-sample (q, k) pairs to ``NEG_INF`` in logit space, exactly like
    key-padding masking.
  * **Tile skipping** — per-tile segment RANGES (min/max segment id, shape
    ``(2, n_tiles)`` int32) ride in as SCALAR-PREFETCH operands
    (``PrefetchScalarGridSpec``).  A (q-tile, k-tile) grid cell whose ranges
    don't overlap is entirely cross-sample: ``pl.when(live)`` skips its
    matmuls, which is exact — a fully-masked tile contributes nothing to
    the streaming softmax statistics.  This is what kills the O(T²)
    padding work: for S similar samples only ~1/S of the grid is live.

Layout matches ``kernels/flash.py`` (GQA-native): the packed batch is B=1,
the grid iterates KV heads — (Hkv, nQ, nK), K innermost — queries arrive
``(Hkv, rep, T, D)``, K/V ``(Hkv, L, D)``, key bias ``(1, L)``, segment ids
``(1, T)`` / ``(1, L)``.  Capacity padding (rows at/after ``offsets[-1]``)
carries segment id S, which matches no real sample, so padded queries and
keys are mutually invisible to real ones by the same equality test.

Differentiable: fused custom_vjp with FlashAttention-style recomputation —
dQ on the forward grid, dK/dV on the transposed grid (Q innermost), both
with the same live-tile skip.  Segment ids, ranges and the key bias are
masks: no gradient.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, lse_finalize, mma_dtype,
                                  p_from_lse, resolve_compute_dtype,
                                  should_interpret)
from repro.kernels.occupancy import ranges_overlap

__all__ = ["flash_attention_varlen_kernel_call"]


def _seg_mask(s, qs, ks, *, rep, tq):
    """Mask cross-sample (q, k) pairs of one tile to NEG_INF.

    ``qs``: (tq,) query segment ids; ``ks``: (tk,) key segment ids.  Row r
    of the fused (rep·tq)-row group tile is query position ``r % tq``
    (rep-major layout), so all rep heads see the same mask row."""
    rows = rep * tq
    qsr = jnp.broadcast_to(qs[None, :], (rep, tq)).reshape(rows, 1)
    return jnp.where(qsr == ks[None, :], s, NEG_INF)


def _fwd_kernel(qrng, krng, q_ref, k_ref, v_ref, kbias_ref, qs_ref, ks_ref,
                o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                scale: float, n_k: int, tq: int, tk: int, compute: str):
    i = pl.program_id(1)
    j = pl.program_id(2)
    rep, _, D = q_ref.shape[1:]
    rows = rep * tq
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ranges_overlap(qrng, krng, i, j))
    def _step():
        q = q_ref[0].astype(sdt).reshape(rows, D)          # (rep·Tq, D)
        k = k_ref[0].astype(sdt)                           # (Tk, D)
        v = v_ref[0].astype(adt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + kbias_ref[0]                               # (Tk,) key-validity bias
        s = _seg_mask(s, qs_ref[0], ks_ref[0], rep=rep, tq=tq)

        m_prev = m_scr[...]                                # (rep·Tq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(adt), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(j == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom).reshape(rep, tq, D).astype(o_ref.dtype)
        m_safe_f = jnp.maximum(m_scr[...], NEG_INF / 2)
        lse_ref[0] = lse_finalize(m_safe_f, l_scr[...])[:, 0].reshape(rep, tq)


def _dq_kernel(qrng, krng, q_ref, k_ref, v_ref, kbias_ref, qs_ref, ks_ref,
               do_ref, lse_ref, delta_ref, dq_ref, dq_scr, *,
               scale: float, n_k: int, tq: int, tk: int, compute: str):
    i = pl.program_id(1)
    j = pl.program_id(2)
    rep, _, D = q_ref.shape[1:]
    rows = rep * tq
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(ranges_overlap(qrng, krng, i, j))
    def _step():
        q = q_ref[0].astype(sdt).reshape(rows, D)
        k = k_ref[0].astype(sdt)
        v = v_ref[0].astype(adt)
        do = do_ref[0].astype(adt).reshape(rows, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + kbias_ref[0]
        s = _seg_mask(s, qs_ref[0], ks_ref[0], rep=rep, tq=tq)
        p = p_from_lse(s, lse_ref[0].reshape(rows, 1))     # (rep·Tq, Tk)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0].reshape(rows, 1)) * scale
        dq_scr[...] += jax.lax.dot_general(ds.astype(adt), k.astype(adt),
                                           (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].reshape(rep, tq, D).astype(dq_ref.dtype)


def _dkv_kernel(qrng, krng, q_ref, k_ref, v_ref, kbias_ref, qs_ref, ks_ref,
                do_ref, lse_ref, delta_ref, dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale: float, n_q: int, tq: int, tk: int, compute: str):
    j = pl.program_id(1)                                   # K tile (outer)
    i = pl.program_id(2)                                   # Q tile (inner)
    rep, _, D = q_ref.shape[1:]
    rows = rep * tq
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(ranges_overlap(qrng, krng, i, j))
    def _step():
        q = q_ref[0].astype(sdt).reshape(rows, D)
        k = k_ref[0].astype(sdt)
        v = v_ref[0].astype(adt)
        do = do_ref[0].astype(adt).reshape(rows, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + kbias_ref[0]
        s = _seg_mask(s, qs_ref[0], ks_ref[0], rep=rep, tq=tq)
        p = p_from_lse(s, lse_ref[0].reshape(rows, 1))
        # (0,)-axis contraction: the GQA group's dK/dV accumulate in-matmul
        dv_scr[...] += jax.lax.dot_general(p.astype(adt), do,
                                           (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0].reshape(rows, 1)) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(adt), q_ref[0].astype(adt).reshape(rows, D),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _fwd_call(q, k, v, key_bias, qseg, kseg, qrng, krng, *, tq, tk,
              interpret, compute):
    BH, rep, N, D = q.shape
    L = k.shape[1]
    n_k = L // tk
    kern = functools.partial(_fwd_kernel, scale=1.0 / (D ** 0.5), n_k=n_k,
                             tq=tq, tk=tk, compute=compute)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, N // tq, n_k),
        in_specs=[
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, qr, kr: (b, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, qr, kr: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, qr, kr: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, i, j, qr, kr: (0, j)),
            pl.BlockSpec((1, tq), lambda b, i, j, qr, kr: (0, i)),
            pl.BlockSpec((1, tk), lambda b, i, j, qr, kr: (0, j)),
        ],
        out_specs=(
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, qr, kr: (b, 0, i, 0)),
            pl.BlockSpec((1, rep, tq), lambda b, i, j, qr, kr: (b, 0, i)),
        ),
        scratch_shapes=[
            pltpu.VMEM((rep * tq, 1), jnp.float32),
            pltpu.VMEM((rep * tq, 1), jnp.float32),
            pltpu.VMEM((rep * tq, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, rep, N), jnp.float32)),
        interpret=interpret,
    )(qrng, krng, q, k, v, key_bias, qseg, kseg)


def _bwd_calls(q, k, v, key_bias, qseg, kseg, qrng, krng, do, lse, delta, *,
               tq, tk, interpret, compute):
    BH, rep, N, D = q.shape
    L = k.shape[1]
    n_q, n_k = N // tq, L // tk
    kw = dict(scale=1.0 / (D ** 0.5), tq=tq, tk=tk, compute=compute)

    dq_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, qr, kr: (b, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, qr, kr: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, qr, kr: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, i, j, qr, kr: (0, j)),
            pl.BlockSpec((1, tq), lambda b, i, j, qr, kr: (0, i)),
            pl.BlockSpec((1, tk), lambda b, i, j, qr, kr: (0, j)),
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, qr, kr: (b, 0, i, 0)),
            pl.BlockSpec((1, rep, tq), lambda b, i, j, qr, kr: (b, 0, i)),
            pl.BlockSpec((1, rep, tq), lambda b, i, j, qr, kr: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, rep, tq, D),
                               lambda b, i, j, qr, kr: (b, 0, i, 0)),
        scratch_shapes=[pltpu.VMEM((rep * tq, D), jnp.float32)],
    )
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, n_k=n_k, **kw),
        grid_spec=dq_spec,
        out_shape=jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
        interpret=interpret,
    )(qrng, krng, q, k, v, key_bias, qseg, kseg, do, lse, delta)

    dkv_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, rep, tq, D), lambda b, j, i, qr, kr: (b, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, j, i, qr, kr: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, j, i, qr, kr: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, j, i, qr, kr: (0, j)),
            pl.BlockSpec((1, tq), lambda b, j, i, qr, kr: (0, i)),
            pl.BlockSpec((1, tk), lambda b, j, i, qr, kr: (0, j)),
            pl.BlockSpec((1, rep, tq, D), lambda b, j, i, qr, kr: (b, 0, i, 0)),
            pl.BlockSpec((1, rep, tq), lambda b, j, i, qr, kr: (b, 0, i)),
            pl.BlockSpec((1, rep, tq), lambda b, j, i, qr, kr: (b, 0, i)),
        ],
        out_specs=(pl.BlockSpec((1, tk, D), lambda b, j, i, qr, kr: (b, j, 0)),
                   pl.BlockSpec((1, tk, D), lambda b, j, i, qr, kr: (b, j, 0))),
        scratch_shapes=[pltpu.VMEM((tk, D), jnp.float32),
                        pltpu.VMEM((tk, D), jnp.float32)],
    )
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, n_q=n_q, **kw),
        grid_spec=dkv_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, L, D), k.dtype),
                   jax.ShapeDtypeStruct((BH, L, D), v.dtype)),
        interpret=interpret,
    )(qrng, krng, q, k, v, key_bias, qseg, kseg, do, lse, delta)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_vjp(tq: int, tk: int, interpret: bool, compute: str):
    kw = dict(tq=tq, tk=tk, interpret=interpret, compute=compute)

    @jax.custom_vjp
    def attend(q, k, v, key_bias, qseg, kseg, qrng, krng):
        return _fwd_call(q, k, v, key_bias, qseg, kseg, qrng, krng, **kw)[0]

    def attend_fwd(q, k, v, key_bias, qseg, kseg, qrng, krng):
        o, lse = _fwd_call(q, k, v, key_bias, qseg, kseg, qrng, krng, **kw)
        return o, (q, k, v, key_bias, qseg, kseg, qrng, krng, o, lse)

    def attend_bwd(res, do):
        q, k, v, key_bias, qseg, kseg, qrng, krng, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        dq, dk, dv = _bwd_calls(q, k, v, key_bias, qseg, kseg, qrng, krng,
                                do, lse, delta, **kw)
        return dq, dk, dv, None, None, None, None, None    # masks/ids: no grad

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


@functools.partial(jax.jit, static_argnames=("tq", "tk", "interpret",
                                             "compute"))
def flash_attention_varlen_kernel_call(q, k, v, key_bias, qseg, kseg,
                                       qrng, krng, *, tq: int = 256,
                                       tk: int = 256,
                                       interpret: bool | None = None,
                                       compute: str | None = None):
    """Packed-varlen flash attention over one concatenated sample axis.

    q: (Hkv, rep, T, D) grouped queries; k, v: (Hkv, L, D); key_bias: (1, L)
    fp32 additive (padding/validity); qseg: (1, T) / kseg: (1, L) int32
    per-position segment ids; qrng: (2, n_q_tiles) / krng: (2, n_k_tiles)
    int32 per-tile [min, max] segment ranges (scalar-prefetched for tile
    skipping).  ``tq`` must divide T and ``tk`` divide L
    (``kernels/ops.flash_attention_varlen`` pads and derives the seg
    operands — direct callers rarely want this entry point).
    Returns (Hkv, rep, T, D).  Differentiable in q, k, v."""
    BH, rep, N, D = q.shape
    L = k.shape[1]
    tq = min(tq, N)
    tk = min(tk, L)
    if N % tq or L % tk:
        raise ValueError(f"tiles must divide the (padded) axes: T={N} tq={tq},"
                         f" L={L} tk={tk} — kernels/ops.flash_attention_varlen"
                         " pads; direct callers must pass dividing tiles")
    if interpret is None:
        interpret = should_interpret()
    if compute is None:
        compute = resolve_compute_dtype(q.dtype)
    if interpret and BH > 1:
        # CPU fallback: per-KV-head grids keep the interpreter linear in Hkv.
        # Bias/seg/range operands are shared across heads — close over them
        # and map only q/k/v (they are also the only differentiable inputs).
        f = _make_vjp(tq, tk, True, compute)

        def one_head(t):
            qh, kh, vh = t
            return f(qh[None], kh[None], vh[None], key_bias, qseg, kseg,
                     qrng, krng)[0]

        return jax.lax.map(one_head, (q, k, v))
    return _make_vjp(tq, tk, interpret, compute)(q, k, v, key_bias, qseg,
                                                 kseg, qrng, krng)
