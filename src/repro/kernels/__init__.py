"""Pallas TPU kernels for the BSA hot paths (ball / compression / selection
attention) — the hardware-aligned implementation the paper leaves as future
work.  ``ops`` holds the jit'd wrappers, ``ref`` the pure-jnp oracles."""
