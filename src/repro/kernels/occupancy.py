"""Tile-occupancy: host-side per-tile liveness precompute + a counter seam.

The varlen kernel (PR 6) proved the pattern: precompute, on the host, which
(q-tile, k-tile) grid cells can possibly contribute — from packed-segment
ranges there — ship the verdicts into the kernel as SCALAR-PREFETCH operands
(``pltpu.PrefetchScalarGridSpec``), and wrap the tile body in ``pl.when`` so
a dead cell issues no matmuls.  This module generalises the precompute to
every liveness source the BSA kernels see:

  * **key-tile validity** — a tile whose keys are ALL masked (additive bias
    ≤ NEG_INF/2) contributes exactly nothing: the kernels zero its p in
    logit space anyway, so skipping it is bit-exact, forward and backward.
  * **query-tile validity** — rows whose queries are padding produce values
    nobody reads (the model masks them at the combine epilogue); a q-tile
    with no valid query can skip, leaving zeros / LSE_EMPTY behind.
  * **causal / block-causal structure** — the static triangular shape of
    the flash mask modes, decided per (i, j) from indices alone.
  * **packed-segment ranges** — the original varlen overlap test, kept here
    so all kernels share one definition.

Every helper returns int32 (the SMEM-friendly prefetch dtype); a cell is
live iff its entry is non-zero.  Liveness is conservative: a live verdict
for a tile that happens to contribute nothing costs only the old (compute
then mask) behaviour; a DEAD verdict must be exact, which each predicate
here guarantees — dead tiles match the repo-wide "all-masked rows produce
exact zeros" contract, so skipped outputs and gradients equal the jnp
oracle bit-for-bit.

``record_occupancy`` / ``record`` are the audit seam: the kernel wrappers
report each launch's live map, and ``benchmarks/perf_iter.py --occupancy``
sums live/total per kernel from one eager forward.  Recording no-ops under
jit (tracers carry no counts) and when no recorder is active.
"""

from __future__ import annotations

import contextlib
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.numerics import NEG_INF

__all__ = ["key_tile_live", "query_tile_live", "causal_tile_live",
           "ring_hop_live",
           "flash_live_map", "tile_seg_ranges", "ranges_overlap",
           "ranges_live_map", "group_live", "invalidate_dead_groups",
           "offsets_digest", "cached_varlen_maps",
           "record_occupancy", "record"]


# ---------------------------------------------------------------------------
# host-side liveness builders
# ---------------------------------------------------------------------------

def key_tile_live(key_bias: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(B, L) fp32 additive key bias → (B, L/tile) bool: does any key of the
    tile carry weight?  A key is dead when its bias is at or below the
    NEG_INF/2 guard — the same threshold the kernels use to zero p, so a
    False here means the tile contributes exactly nothing."""
    B, L = key_bias.shape
    return (key_bias.reshape(B, L // tile, tile) > NEG_INF / 2).any(-1)


def query_tile_live(q_valid: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(B, N) bool query validity → (B, N/tile) bool any-valid-query."""
    B, N = q_valid.shape
    return q_valid.reshape(B, N // tile, tile).any(-1)


def causal_tile_live(n_q: int, n_k: int, tq: int, tk: int, *,
                     causal: bool = False, block_causal: bool = False,
                     ell: int = 1) -> np.ndarray:
    """Static (nQ, nK) bool structural liveness of the flash mask modes.

    Tile (i, j) is live iff ANY (q, k) pair inside it passes the mask; the
    extreme pair is (last query of tile i, first key of tile j) because both
    masks are monotone in the query position and anti-monotone in the key
    index.  Plain mode: everything live.  Pure numpy — the verdicts depend
    only on static tile geometry."""
    qmax = (np.arange(n_q) + 1) * tq - 1          # last query position, tile i
    kmin = np.arange(n_k) * tk                    # first key index, tile j
    if block_causal:
        ok = (kmin[None, :] + 1) * ell - 1 < qmax[:, None]
    elif causal:
        ok = kmin[None, :] <= qmax[:, None]
    else:
        ok = np.ones((n_q, n_k), bool)
    return ok


def ring_hop_live(p: int, n_loc: int, *, causal: bool = False,
                  block_causal: bool = False, ell: int = 1) -> np.ndarray:
    """Static (p, p) bool hop liveness for the ring-rotation schedule.

    ``live[i, h]``: does hop ``h`` contribute anything on shard ``i``?  Hop
    ``h`` leaves shard ``i`` holding the K/V slab originated by shard
    ``(i − h) mod p``, so this is exactly :func:`causal_tile_live` at tile
    size ``n_loc`` (shard slabs ARE tiles) reindexed from (q-tile i,
    k-tile src) to (shard i, hop h).  Token-causal: live iff ``h ≤ i`` —
    ``p(p+1)/2`` of ``p²`` hops, the ~half-work claim of the causal ring."""
    tl = causal_tile_live(p, p, n_loc, n_loc, causal=causal,
                          block_causal=block_causal, ell=ell)
    i = np.arange(p)[:, None]
    h = np.arange(p)[None, :]
    return tl[i, (i - h) % p]


def flash_live_map(key_bias: jnp.ndarray, tq: int, tk: int, n_q: int, *,
                   q_valid: jnp.ndarray | None = None, causal: bool = False,
                   block_causal: bool = False, ell: int = 1) -> jnp.ndarray:
    """Combined (B, nQ, nK) int32 prefetch map for the flash kernels: a cell
    is live iff its key tile has a valid key AND (when ``q_valid`` is given)
    its query tile has a valid query AND the causal structure admits it."""
    kt = key_tile_live(key_bias, tk)                       # (B, nK)
    live = jnp.broadcast_to(kt[:, None, :], (kt.shape[0], n_q, kt.shape[1]))
    if q_valid is not None:
        live = live & query_tile_live(q_valid, tq)[:, :, None]
    struct = causal_tile_live(n_q, kt.shape[1], tq, tk, causal=causal,
                              block_causal=block_causal, ell=ell)
    return (live & jnp.asarray(struct)[None]).astype(jnp.int32)


def tile_seg_ranges(seg: jnp.ndarray, tile: int) -> jnp.ndarray:
    """(Tp,) monotone segment ids → (2, Tp/tile) per-tile [min, max] int32
    (the scalar-prefetch operand of the varlen kernel)."""
    blocks = seg.reshape(-1, tile)
    return jnp.stack([blocks[:, 0], blocks[:, -1]]).astype(jnp.int32)


def ranges_overlap(qrng, krng, i, j):
    """In-kernel: do q-tile i and k-tile j share at least one segment id?

    Segment ids are monotone along the packed axis, so the per-tile
    [min, max] ranges overlap iff some sample has rows in both tiles."""
    return (krng[0, j] <= qrng[1, i]) & (qrng[0, i] <= krng[1, j])


def ranges_live_map(qrng: jnp.ndarray, krng: jnp.ndarray) -> jnp.ndarray:
    """Host-side twin of ``ranges_overlap``: (2, nQ) × (2, nK) → (nQ, nK)
    bool — what the varlen grid will actually run (used for auditing)."""
    return ((krng[0][None, :] <= qrng[1][:, None])
            & (qrng[0][:, None] <= krng[1][None, :]))


# ---------------------------------------------------------------------------
# cached varlen maps — ragged steps reuse identical host-side precomputes
# ---------------------------------------------------------------------------

def offsets_digest(offsets):
    """Hashable identity of a CONCRETE offsets array (tuple of ints), or
    None when ``offsets`` is a tracer — the cache key's ragged half."""
    if isinstance(offsets, jax.core.Tracer):
        return None
    return tuple(int(x) for x in np.asarray(offsets).reshape(-1))


@functools.lru_cache(maxsize=128)
def _varlen_maps(q_key: tuple, k_key: tuple, Tp: int, Lp: int,
                 tq: int, tk: int):
    """Numpy twin of the per-call map build in ``ops.flash_attention_varlen``
    (segment ids via searchsorted + per-tile [min, max] ranges), memoised on
    (offsets digest, tile config) so repeated ragged steps with the same
    batch layout stop rebuilding identical maps every invocation."""

    def seg_ids(key, length):
        bounds = np.asarray(key, np.int32)[1:]
        return np.searchsorted(bounds, np.arange(length, dtype=np.int32),
                               side="right").astype(np.int32)

    def ranges(seg, tile):
        blocks = seg.reshape(-1, tile)
        return np.stack([blocks[:, 0], blocks[:, -1]]).astype(np.int32)

    qseg = seg_ids(q_key, Tp)
    kseg = seg_ids(k_key, Lp)
    return qseg, kseg, ranges(qseg, tq), ranges(kseg, tk)


def cached_varlen_maps(q_offsets, k_offsets, Tp: int, Lp: int,
                       tq: int, tk: int):
    """(qseg, kseg, qrng, krng) for the varlen kernel's scalar prefetch.

    Concrete offsets hit the host-side LRU (numpy, hashable digests);
    tracers fall back to the traced jnp build — same arrays either way."""
    qd, kd = offsets_digest(q_offsets), offsets_digest(k_offsets)
    if qd is not None and kd is not None:
        qseg, kseg, qrng, krng = _varlen_maps(qd, kd, Tp, Lp, tq, tk)
        return (jnp.asarray(qseg), jnp.asarray(kseg),
                jnp.asarray(qrng), jnp.asarray(krng))
    from repro.numerics import segment_ids_from_offsets
    qseg = segment_ids_from_offsets(q_offsets, Tp)
    kseg = segment_ids_from_offsets(k_offsets, Lp)
    return qseg, kseg, tile_seg_ranges(qseg, tq), tile_seg_ranges(kseg, tk)


def group_live(mask: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """(B, N) bool token validity → (B, G) bool: any valid token in the
    query group."""
    B, N = mask.shape
    return mask.reshape(B, n_groups, N // n_groups).any(-1)


def invalidate_dead_groups(sel_valid: jnp.ndarray, mask: jnp.ndarray | None,
                           n_tokens: int) -> jnp.ndarray:
    """Mark every selection of an all-masked query group invalid.

    ``sel_valid``: (B, G, …) selection validity; ``mask``: (B, N) bool token
    validity or None.  A group whose query tokens are all padding produces
    rows nobody reads — invalidating its selections lets the kernel skip
    those grid cells AND makes the jnp oracle emit exact zeros for them, so
    both paths agree (the shared contract all selection front-ends apply)."""
    if mask is None:
        return sel_valid
    G = sel_valid.shape[1]
    live = group_live(mask[:, :n_tokens], G)               # (B, G)
    return sel_valid & live[(...,) + (None,) * (sel_valid.ndim - 2)]


# ---------------------------------------------------------------------------
# occupancy recording (the --occupancy audit seam)
# ---------------------------------------------------------------------------

_TLS = threading.local()


@contextlib.contextmanager
def record_occupancy():
    """Collect per-kernel {live, total} tile counts from wrapper launches.

        with record_occupancy() as counts:
            bsa_attention(...)            # eager — tracers are not counted
        counts == {"flash": {"live": 11, "total": 16}, ...}

    Counts are per KV head and per launch (grid cells over the batch·tile
    axes); nested recorders shadow the outer one."""
    counts: dict = {}
    prev = getattr(_TLS, "counts", None)
    _TLS.counts = counts
    try:
        yield counts
    finally:
        _TLS.counts = prev


def record(kernel: str, live) -> None:
    """Report one launch's liveness array (any shape; non-zero = live).

    No-op when no recorder is active or ``live`` is a tracer (jitted calls
    cannot be counted — run the forward eagerly to audit)."""
    counts = getattr(_TLS, "counts", None)
    if counts is None or isinstance(live, jax.core.Tracer):
        return
    arr = np.asarray(live)
    entry = counts.setdefault(kernel, {"live": 0, "total": 0})
    entry["live"] += int((arr != 0).sum())
    entry["total"] += int(arr.size)
