"""jit'd wrappers: layout transforms between core tensor convention
(B, N, H, D) and the kernels' flattened (B·H, N, D) / blocked layouts.

These are the entry points the "pallas" / "interpret" attention backends
(``repro.core.backend.PallasBackend``) dispatch to.

Shape/dtype contract (shared by all four wrappers):

  * q is (B, N, Hq, D); k, v are (B, L, Hkv, D).  The wrappers take EQUAL
    head counts (``selection_attention`` excepted): GQA repetition
    (Hq = Hkv·rep) is materialised by the caller via
    ``repro.core.branches.repeat_kv`` before entering the kernel layout.
  * ``mask`` / ``key_valid`` is a (B, L) bool array, True = real token.
    It masks KEYS only — padded queries still compute rows (they are cheap
    and keep shapes static); the model zeroes their outputs.  Internally the
    mask becomes an additive fp32 key bias (0 valid / NEG_INF = −1e30
    padding) applied in LOGIT space, which is also exactly what the fused
    backward kernels recompute — so masked keys receive exactly zero
    gradient.  A query row whose keys are ALL masked returns zeros.
  * Any floating dtype is accepted (fp32 and bf16 are tested); softmax
    statistics are always fp32 inside the kernels.

Batched (ragged) geometries: every wrapper carries a leading batch dim, so a
packed batch of variable-size samples — one mask row per sample, produced by
``repro.core.balltree.pack_ragged`` — is a single kernel launch.

All wrappers are differentiable in q/k/v: the kernel calls carry
``jax.custom_vjp`` fused backward passes (see each kernel module), and the
layout transforms here are plain jnp ops, so ``jax.grad`` through
``bsa_attention`` / ``nsa_causal_attention`` works on the kernel backends.
Mask-derived biases are non-differentiable by construction (their cotangent
is dropped in the kernel VJPs).  Every wrapper takes ``interpret`` (None =
auto-detect, True = force Pallas interpret mode — the "interpret" backend).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.bta import ball_attention_kernel_call
from repro.kernels.flash import flash_attention_kernel_call
from repro.kernels.local import local_window_kernel_call
from repro.kernels.selection import selection_attention_kernel_call
from repro.numerics import NEG_INF, key_padding_bias

__all__ = ["ball_attention", "flash_attention", "local_window_attention",
           "selection_attention"]


def _to_bh(t):
    """(B, N, H, D) → (B·H, N, D)"""
    B, N, H, D = t.shape
    return t.transpose(0, 2, 1, 3).reshape(B * H, N, D)


def _from_bh(t, B, H):
    BH, N, D = t.shape
    return t.reshape(B, H, N, D).transpose(0, 2, 1, 3)


def ball_attention(q, k, v, mask, ball_size: int, *,
                   interpret: bool | None = None):
    """Ball-Tree Attention: full attention inside each contiguous ball.

    q, k, v: (B, N, H, D) EQUAL head counts (repeat KV first for GQA);
    ``mask``: (B, N) bool (True = real) or None — masks keys in logit space,
    one row per sample of a packed ragged batch.  ``ball_size`` must divide
    N.  ``interpret`` forces Pallas interpret mode (None = auto-detect).
    Returns (B, N, H, D).  Differentiable in q, k, v.
    """
    B, N, H, D = q.shape
    out = ball_attention_kernel_call(
        _to_bh(q), _to_bh(k), _to_bh(v), key_padding_bias(mask, B, N),
        ball_size=ball_size, n_heads=H, interpret=interpret)
    return _from_bh(out, B, H)


def flash_attention(q, k, v, *, key_valid=None, causal=False,
                    block_causal=False, ell=1, bias=None,
                    tq: int = 256, tk: int = 256,
                    interpret: bool | None = None):
    """Streaming-softmax attention of q vs an arbitrary-length K/V.

    q: (B, N, H, D); k, v: (B, L, H, D) equal head counts (L may differ from
    N — the compression branch attends N queries to L = N/ℓ coarse tokens).

    ``key_valid``: (B, L) bool, True = real key (per-sample row of a packed
    ragged batch).  ``causal``: token-level lower-triangular mask (needs
    L == N).  ``block_causal``: coarse-block causality with block length
    ``ell`` — query t sees coarse key j iff (j+1)·ell − 1 < t; the mask is
    generated in-kernel from indices and never materialised.  ``bias``:
    (B, 1, 1, L) fp32 additive key bias accepted as an alternative to
    ``key_valid`` (the two add if both given).  ``tq``/``tk`` are tile-size
    preferences (clamped to divisors of N/L).  Returns (B, N, H, D).
    Differentiable in q, k, v."""
    B, N, H, D = q.shape
    L = k.shape[1]
    kb = key_padding_bias(key_valid, B, L)
    if bias is not None:
        kb = kb + bias.reshape(B, L).astype(jnp.float32)
    out = flash_attention_kernel_call(
        _to_bh(q), _to_bh(k), _to_bh(v), kb, n_heads=H,
        causal=causal, block_causal=block_causal, ell=ell, tq=tq, tk=tk,
        interpret=interpret)
    return _from_bh(out, B, H)


def local_window_attention(q, k, v, window: int, mask=None, *,
                           interpret: bool | None = None):
    """Blocked local causal attention (the LM 'ball' branch).

    q, k, v: (B, N, H, D) equal head counts; query block i (size ``window``)
    attends causally within itself and fully to block i−1.  ``mask``:
    (B, N) bool (True = real) or None — key-validity for packed ragged
    batches, applied in logit space inside the kernel.  Returns
    (B, N, H, D).  Differentiable in q, k, v."""
    B, N, H, D = q.shape
    out = local_window_kernel_call(
        _to_bh(q), _to_bh(k), _to_bh(v), key_padding_bias(mask, B, N),
        window=window, n_heads=H, interpret=interpret)
    return _from_bh(out, B, H)


def selection_attention(q, k, v, top_idx, sel_valid, mask, *,
                        block_size: int, group_size: int,
                        interpret: bool | None = None):
    """Group-selected sparse attention via the scalar-prefetch kernel.

    q: (B, N, Hq, D); k, v: (B, N, Hkv, D) with Hq = Hkv·rep (GQA — the only
    wrapper that takes the un-repeated KV: all rep query heads of a group
    share one fetched block set, which is the point of group selection).
    ``top_idx``/``sel_valid``: (B, G, Hkv, k*) — per query group and KV head,
    the selected coarse-block ids and their validity (invalid selections are
    encoded as index −1 for the kernel and skipped).  ``mask``: (B, N) bool
    or None — token validity of the GATHERED keys (padding inside a selected
    block is masked in logit space).  ``block_size`` ℓ is the KV block
    length; ``group_size`` g = N/G tokens per query group.  Returns
    (B, N, Hq, D).  Differentiable in q, k, v (dK/dV are scatter-added back
    through the gathered indices)."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = block_size
    nb = N // ell
    G = top_idx.shape[1]
    g = N // G

    qg = (q.reshape(B, G, g, Hkv, rep, D)
           .transpose(0, 3, 1, 2, 4, 5)
           .reshape(B, Hkv, G, g * rep, D))
    kb = k.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)   # (B,Hkv,NB,ℓ,D)
    vb = v.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    idx = jnp.where(sel_valid, top_idx, -1).astype(jnp.int32)
    idx = idx.transpose(0, 2, 1, 3)                               # (B,Hkv,G,k*)
    if mask is None:
        tok_bias = jnp.zeros((B, nb, ell), jnp.float32)
    else:
        tok_bias = jnp.where(mask.reshape(B, nb, ell), 0.0, NEG_INF).astype(jnp.float32)

    out = selection_attention_kernel_call(qg, kb, vb, idx, tok_bias,
                                          interpret=interpret)
    return (out.reshape(B, Hkv, G, g, rep, D)
               .transpose(0, 2, 3, 1, 4, 5)
               .reshape(B, N, Hq, D))
