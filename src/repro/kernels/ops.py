"""jit'd wrappers: layout transforms between core tensor convention
(B, N, H, D) and the kernels' flattened (B·H, N, D) / blocked layouts.

These are the entry points ``repro.core`` uses when ``cfg.use_kernels``.

All wrappers are differentiable in q/k/v: the kernel calls carry
``jax.custom_vjp`` fused backward passes (see each kernel module), and the
layout transforms here are plain jnp ops, so ``jax.grad`` through
``bsa_attention`` / ``nsa_causal_attention`` works with ``use_kernels=True``.
Mask-derived biases are non-differentiable by construction (their cotangent
is dropped in the kernel VJPs).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.bta import ball_attention_kernel_call
from repro.kernels.common import NEG_INF
from repro.kernels.flash import flash_attention_kernel_call
from repro.kernels.local import local_window_kernel_call
from repro.kernels.selection import selection_attention_kernel_call

__all__ = ["ball_attention", "flash_attention", "local_window_attention",
           "selection_attention"]


def _to_bh(t):
    """(B, N, H, D) → (B·H, N, D)"""
    B, N, H, D = t.shape
    return t.transpose(0, 2, 1, 3).reshape(B * H, N, D)


def _from_bh(t, B, H):
    BH, N, D = t.shape
    return t.reshape(B, H, N, D).transpose(0, 2, 1, 3)


def _key_bias(mask, B, L):
    if mask is None:
        return jnp.zeros((B, L), jnp.float32)
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


def ball_attention(q, k, v, mask, ball_size: int):
    """q,k,v: (B,N,H,D) equal head counts; mask: (B,N) bool or None."""
    B, N, H, D = q.shape
    out = ball_attention_kernel_call(
        _to_bh(q), _to_bh(k), _to_bh(v), _key_bias(mask, B, N),
        ball_size=ball_size, n_heads=H)
    return _from_bh(out, B, H)


def flash_attention(q, k, v, *, key_valid=None, causal=False,
                    block_causal=False, ell=1, bias=None,
                    tq: int = 256, tk: int = 256):
    """q: (B,N,H,D); k,v: (B,L,H,D) equal head counts.

    key_valid: (B, L) bool.  ``causal``: token-level; ``block_causal``:
    coarse-block causality with block length ``ell`` (compression branch).
    ``bias`` (B,1,1,L) fp32 is accepted as an alternative key bias."""
    B, N, H, D = q.shape
    L = k.shape[1]
    kb = _key_bias(key_valid, B, L)
    if bias is not None:
        kb = kb + bias.reshape(B, L).astype(jnp.float32)
    out = flash_attention_kernel_call(
        _to_bh(q), _to_bh(k), _to_bh(v), kb, n_heads=H,
        causal=causal, block_causal=block_causal, ell=ell, tq=tq, tk=tk)
    return _from_bh(out, B, H)


def local_window_attention(q, k, v, window: int):
    """q,k,v: (B,N,H,D) equal head counts."""
    B, N, H, D = q.shape
    out = local_window_kernel_call(_to_bh(q), _to_bh(k), _to_bh(v), window=window)
    return _from_bh(out, B, H)


def selection_attention(q, k, v, top_idx, sel_valid, mask, *,
                        block_size: int, group_size: int):
    """Group-selected sparse attention via the scalar-prefetch kernel.

    q: (B,N,Hq,D); k,v: (B,N,Hkv,D); top_idx/sel_valid: (B,G,Hkv,k*);
    mask: (B,N) bool or None.  Returns (B,N,Hq,D)."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = block_size
    nb = N // ell
    G = top_idx.shape[1]
    g = N // G

    qg = (q.reshape(B, G, g, Hkv, rep, D)
           .transpose(0, 3, 1, 2, 4, 5)
           .reshape(B, Hkv, G, g * rep, D))
    kb = k.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)   # (B,Hkv,NB,ℓ,D)
    vb = v.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    idx = jnp.where(sel_valid, top_idx, -1).astype(jnp.int32)
    idx = idx.transpose(0, 2, 1, 3)                               # (B,Hkv,G,k*)
    if mask is None:
        tok_bias = jnp.zeros((B, nb, ell), jnp.float32)
    else:
        tok_bias = jnp.where(mask.reshape(B, nb, ell), 0.0, NEG_INF).astype(jnp.float32)

    out = selection_attention_kernel_call(qg, kb, vb, idx, tok_bias)
    return (out.reshape(B, Hkv, G, g, rep, D)
               .transpose(0, 2, 3, 1, 4, 5)
               .reshape(B, N, Hq, D))
