"""jit'd wrappers: layout transforms between core tensor convention
(B, N, H, D) and the kernels' GQA-grouped (B·Hkv, rep, N, D) / blocked
layouts.

These are the entry points the "pallas" / "interpret" attention backends
(``repro.core.backend.PallasBackend``) dispatch to.

Shape/dtype contract (shared by all four attention wrappers):

  * q is (B, N, Hq, D); k, v are (B, L, Hkv, D) with Hq = Hkv·rep.  The
    kernels are GQA-NATIVE: K/V are NEVER head-repeated — each kernel's grid
    iterates KV heads and a group's ``rep`` query heads share one fetched
    K/V tile, folded into the matmul row dimension (forward and fused
    backward; dK/dV accumulate across the group inside the contraction).
    Query head h·rep + r belongs to KV head h (the ``branches.repeat_kv``
    convention, kept so the jnp reference pins semantics).
  * ``mask`` / ``key_valid`` is a (B, L) bool array, True = real token.
    It masks KEYS only.  Internally the mask becomes an additive fp32 key
    bias (0 valid / NEG_INF = −1e30 padding) applied in LOGIT space, which
    is also exactly what the fused backward kernels recompute — so masked
    keys receive exactly zero gradient.  A query row whose keys are ALL
    masked returns zeros.
  * ``q_valid`` (where accepted) is an OPTIMIZATION-ONLY hint: rows whose
    queries are padding produce UNSPECIFIED values (the kernels may skip
    whole dead q-tiles, leaving zeros; the jnp backend ignores the hint) —
    the model masks padded rows at the combine epilogue either way.
  * Any floating dtype is accepted (fp32 and bf16 are tested); softmax
    statistics are always fp32 inside the kernels.  The matmul-OPERAND
    dtype follows the kernel precision contract
    (``common.resolve_compute_dtype``): bf16 inputs keep bf16 tiles through
    QK^T and PV with fp32 accumulation; REPRO_FP8=1 opts QK^T into fp8.
  * TILE-OCCUPANCY SKIPPING (``kernels/occupancy.py``): every wrapper
    precomputes per-tile liveness from its mask / causal structure /
    offsets, ships it to the kernel as a scalar-prefetch operand, and
    reports it to ``occupancy.record`` so ``perf_iter.py --occupancy`` can
    audit the live/total tile ratio.

Tiles and padding: ``flash_attention`` resolves its (tq, tk) tiles through
``kernels/tuning.py`` (cache → autotune → deterministic heuristic) and PADS
the query/key axes up to tile multiples — padded keys carry NEG_INF bias
(zero weight, zero gradient), padded query rows are sliced off — so ragged
lengths with no friendly divisor no longer collapse the tile size to 1.

Batched (ragged) geometries: every wrapper carries a leading batch dim, so a
packed batch of variable-size samples — one mask row per sample, produced by
``repro.core.balltree.pack_ragged`` — is a single kernel launch.

All wrappers are differentiable in their floating inputs: the kernel calls
carry ``jax.custom_vjp`` fused backward passes (see each kernel module), and
the layout transforms here are plain jnp ops, so ``jax.grad`` through
``bsa_attention`` / ``nsa_causal_attention`` works on the kernel backends.
Mask-derived biases are non-differentiable by construction (their cotangent
is dropped in the kernel VJPs).  Every wrapper takes ``interpret`` (None =
auto-detect, True = force Pallas interpret mode — the "interpret" backend).

``gated_combine`` is the fifth op: the fused branch-combination epilogue
(see ``kernels/epilogue.py``), differentiable in branch outputs and gates.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import occupancy, tuning
from repro.kernels.bta import ball_attention_kernel_call
from repro.kernels.common import resolve_compute_dtype
from repro.kernels.epilogue import gated_combine_kernel_call
from repro.kernels.flash import flash_attention_kernel_call
from repro.kernels.local import local_window_kernel_call
from repro.kernels.selection import selection_attention_kernel_call
from repro.kernels.varlen import flash_attention_varlen_kernel_call
from repro.numerics import (NEG_INF, key_padding_bias,
                            segment_ids_from_offsets)

__all__ = ["ball_attention", "flash_attention", "local_window_attention",
           "selection_attention", "gated_combine",
           "ball_attention_varlen", "flash_attention_varlen",
           "local_window_attention_varlen", "selection_attention_varlen"]


def _to_bh(t):
    """(B, L, Hkv, D) → (B·Hkv, L, D) — the single-K/V-stream-per-head layout."""
    B, L, H, D = t.shape
    return t.transpose(0, 2, 1, 3).reshape(B * H, L, D)


def _to_grouped(q, Hkv):
    """(B, N, Hq, D) → (B·Hkv, rep, N, D): query head h·rep + r rides KV
    head h's grid cells as fused matmul rows (GQA-native kernel layout)."""
    B, N, Hq, D = q.shape
    rep = Hq // Hkv
    return (q.reshape(B, N, Hkv, rep, D)
             .transpose(0, 2, 3, 1, 4)
             .reshape(B * Hkv, rep, N, D))


def _from_grouped(o, B, Hkv):
    BH, rep, N, D = o.shape
    return (o.reshape(B, Hkv, rep, N, D)
             .transpose(0, 3, 1, 2, 4)
             .reshape(B, N, Hkv * rep, D))


def ball_attention(q, k, v, mask, ball_size: int, *,
                   interpret: bool | None = None):
    """Ball-Tree Attention: full attention inside each contiguous ball.

    q: (B, N, Hq, D); k, v: (B, N, Hkv, D) with Hq = Hkv·rep — GQA-native,
    no KV repetition; ``mask``: (B, N) bool (True = real) or None — masks
    keys in logit space, one row per sample of a packed ragged batch.
    ``ball_size`` must divide N.  ``interpret`` forces Pallas interpret mode
    (None = auto-detect).  Returns (B, N, Hq, D).  Differentiable in q, k, v.
    """
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    kb = key_padding_bias(mask, B, N)
    occupancy.record("bta", occupancy.key_tile_live(kb, ball_size))
    out = ball_attention_kernel_call(
        _to_grouped(q, Hkv), _to_bh(k), _to_bh(v), kb,
        ball_size=ball_size, n_heads=Hkv, interpret=interpret,
        compute=resolve_compute_dtype(q.dtype))
    return _from_grouped(out, B, Hkv)


def flash_attention(q, k, v, *, key_valid=None, causal=False,
                    block_causal=False, ell=1, bias=None, q_valid=None,
                    tq: int | None = None, tk: int | None = None,
                    interpret: bool | None = None):
    """Streaming-softmax attention of q vs an arbitrary-length K/V.

    q: (B, N, Hq, D); k, v: (B, L, Hkv, D) with Hq = Hkv·rep (GQA-native; L
    may differ from N — the compression branch attends N queries to L = N/ℓ
    coarse tokens).

    ``key_valid``: (B, L) bool, True = real key (per-sample row of a packed
    ragged batch).  ``causal``: token-level lower-triangular mask (needs
    L == N).  ``block_causal``: coarse-block causality with block length
    ``ell`` — query t sees coarse key j iff (j+1)·ell − 1 < t; the mask is
    generated in-kernel from indices and never materialised.  ``bias``:
    (B, 1, 1, L) fp32 additive key bias accepted as an alternative to
    ``key_valid`` (the two add if both given).  ``tq``/``tk`` override the
    tile sizes; left as None they resolve through the ``kernels/tuning.py``
    autotuner (cache → measure → heuristic).  Axes that are not tile
    multiples are PADDED (masked keys / sliced query rows), never shrunk to
    degenerate tiles.  Returns (B, N, Hq, D).  Differentiable in q, k, v."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    L = k.shape[1]
    if interpret is None:
        from repro.kernels.common import should_interpret
        interpret = should_interpret()
    compute = resolve_compute_dtype(q.dtype)
    if tq is None or tk is None:
        atq, atk = tuning.get_tiles(
            "flash", n_q=N, n_k=L, d=D, dtype=q.dtype, interpret=interpret,
            variant=tuning.flash_variant(causal, block_causal, ell),
            compute=compute,
            measure=_flash_measure(N, L, D, q.dtype, causal, block_causal,
                                   ell, interpret))
        tq = tq or atq
        tk = tk or atk
    tq, tk = min(tq, tuning.round_up(N, 8)), min(tk, tuning.round_up(L, 8))

    kb = key_padding_bias(key_valid, B, L)
    if bias is not None:
        kb = kb + bias.reshape(B, L).astype(jnp.float32)

    # pad axes to tile multiples: padded keys get NEG_INF bias (zero weight,
    # zero grad), padded query rows compute garbage and are sliced off
    Np, Lp = tuning.round_up(N, tq), tuning.round_up(L, tk)
    if Lp != L:
        k = jnp.pad(k, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        kb = jnp.pad(kb, ((0, 0), (0, Lp - L)), constant_values=NEG_INF)
    if Np != N:
        q = jnp.pad(q, ((0, 0), (0, Np - N), (0, 0), (0, 0)))
        if q_valid is not None:
            q_valid = jnp.pad(q_valid, ((0, 0), (0, Np - N)))

    live = occupancy.flash_live_map(kb, tq, tk, Np // tq, q_valid=q_valid,
                                    causal=causal, block_causal=block_causal,
                                    ell=ell)
    occupancy.record("flash", live)
    out = flash_attention_kernel_call(
        _to_grouped(q, Hkv), _to_bh(k), _to_bh(v), kb, live, n_heads=Hkv,
        causal=causal, block_causal=block_causal, ell=ell, tq=tq, tk=tk,
        interpret=interpret, compute=compute)
    out = _from_grouped(out, B, Hkv)
    return out[:, :N] if Np != N else out


def _flash_measure(N, L, D, dtype, causal, block_causal, ell, interpret):
    """Measure callback for the tuner — only invoked on a cache miss with
    autotuning enabled (``tuning.get_tiles`` owns that policy)."""
    if not tuning.autotune_enabled():
        return None

    def measure(tq, tk):
        from repro.kernels.tuning import tune_measure_flash
        return tune_measure_flash(tq, tk, n_q=N, n_k=L, d=D, dtype=dtype,
                                  causal=causal, block_causal=block_causal,
                                  ell=ell, interpret=interpret)
    return measure


def local_window_attention(q, k, v, window: int, mask=None, *,
                           interpret: bool | None = None):
    """Blocked local causal attention (the LM 'ball' branch).

    q: (B, N, Hq, D); k, v: (B, N, Hkv, D) with Hq = Hkv·rep (GQA-native);
    query block i (size ``window``) attends causally within itself and fully
    to block i−1.  ``mask``: (B, N) bool (True = real) or None — key-validity
    for packed ragged batches, applied in logit space inside the kernel.
    Returns (B, N, Hq, D).  Differentiable in q, k, v."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    kb = key_padding_bias(mask, B, N)
    occupancy.record("local", _local_half_live(kb, window))
    out = local_window_kernel_call(
        _to_grouped(q, Hkv), _to_bh(k), _to_bh(v), kb,
        window=window, n_heads=Hkv, interpret=interpret,
        compute=resolve_compute_dtype(q.dtype))
    return _from_grouped(out, B, Hkv)


def _local_half_live(key_bias, window, blk_seg=None):
    """(B, n_b, 2) bool — the two ``pl.when`` half-steps of each local grid
    cell (prev half, self half), exactly what ``kernels/local.py`` skips."""
    kv = occupancy.key_tile_live(key_bias, window)            # (B, n_b)
    self_live = kv
    prev_live = jnp.pad(kv[:, :-1], ((0, 0), (1, 0)))         # block 0: none
    if blk_seg is not None:
        same = jnp.pad(blk_seg[:, 1:] == blk_seg[:, :-1], ((0, 0), (1, 0)))
        prev_live = prev_live & same
    return jnp.stack([prev_live, self_live], axis=-1)


def selection_attention(q, k, v, top_idx, sel_valid, mask, *,
                        block_size: int, group_size: int,
                        interpret: bool | None = None, q_valid=None):
    """Group-selected sparse attention via the scalar-prefetch kernel.

    q: (B, N, Hq, D); k, v: (B, L, Hkv, D) with Hq = Hkv·rep (GQA-native
    from day one: all rep query heads of a group share one fetched block
    set, which is the point of group selection).  L may exceed N — the
    kernel grid is independent in G and NB, so a context-parallel shard can
    pass its local query slab against the full gathered key set.
    ``top_idx``/``sel_valid``: (B, G, Hkv, k*) — per query group and KV head,
    the selected coarse-block ids and their validity (invalid selections are
    encoded as index −1 for the kernel and skipped).  ``mask``: (B, L) bool
    or None — token validity of the GATHERED keys (padding inside a selected
    block is masked in logit space).  ``q_valid``: (B, N) bool or None —
    query-side validity when it differs from the key mask (sharded callers);
    defaults to ``mask`` under the classic N == L layout.  ``block_size``
    ℓ is the KV block length; ``group_size`` g = N/G tokens per query group.
    Returns (B, N, Hq, D).  Differentiable in q, k, v (dK/dV are
    scatter-added back through the gathered indices)."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = block_size
    nb = k.shape[1] // ell
    G = top_idx.shape[1]
    g = N // G

    qg = (q.reshape(B, G, g, Hkv, rep, D)
           .transpose(0, 3, 1, 2, 4, 5)
           .reshape(B, Hkv, G, g * rep, D))
    kb = k.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)   # (B,Hkv,NB,ℓ,D)
    vb = v.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    sel_valid = occupancy.invalidate_dead_groups(
        sel_valid, q_valid if q_valid is not None else mask, N)
    idx = jnp.where(sel_valid, top_idx, -1).astype(jnp.int32)
    idx = idx.transpose(0, 2, 1, 3)                               # (B,Hkv,G,k*)
    if mask is None:
        tok_bias = jnp.zeros((B, nb, ell), jnp.float32)
    else:
        tok_bias = jnp.where(mask.reshape(B, nb, ell), 0.0, NEG_INF).astype(jnp.float32)

    occupancy.record("selection", idx >= 0)
    out = selection_attention_kernel_call(qg, kb, vb, idx, tok_bias,
                                          interpret=interpret,
                                          compute=resolve_compute_dtype(q.dtype))
    return (out.reshape(B, Hkv, G, g, rep, D)
               .transpose(0, 2, 3, 1, 4, 5)
               .reshape(B, N, Hq, D))


# ---------------------------------------------------------------------------
# Packed-varlen wrappers (the offsets layout — see docs/varlen.md)
#
# Shared contract: NO batch dim.  All samples are concatenated on one packed
# axis (``core.balltree.pack_varlen``): q (T, Hq, D), k/v (L, Hkv, D), with
# ``offsets`` (S+1,) int32 marking per-sample boundaries — every entry a
# multiple of the structural granule (ball size), trailing repeats = empty
# segments.  ``mask`` / ``key_valid`` is the packed (T,)/(L,) bool validity.
# Sample isolation comes from in-kernel segment-id masking plus tile
# skipping (``kernels/varlen.py``), or from the structural guarantee that
# balls / blocks never straddle an offsets boundary.
# ---------------------------------------------------------------------------

def flash_attention_varlen(q, k, v, q_offsets, k_offsets, *, key_valid=None,
                           tq: int | None = None, tk: int | None = None,
                           interpret: bool | None = None):
    """Packed-varlen streaming-softmax attention (the cu_seqlens idiom).

    q: (T, Hq, D) packed queries; k, v: (L, Hkv, D) packed keys/values with
    Hq = Hkv·rep (GQA-native).  ``q_offsets`` (S+1,) / ``k_offsets`` (S+1,)
    int32 mark the per-sample boundaries of the two axes — segment i of the
    queries attends ONLY segment i of the keys (the compression branch
    passes ``k_offsets = q_offsets // ell`` for its pooled key axis).
    ``key_valid``: (L,) bool, True = real key.  Derives per-position segment
    ids and per-tile segment ranges, pads both axes to tile multiples
    (padded keys: NEG_INF bias; padded/capacity query rows attend nothing
    real and are sliced/zeroed), and launches the tile-skipping varlen
    kernel — cross-sample tiles are skipped entirely, so work scales with
    Σ nᵢ² per sample instead of T².  Tiles resolve through
    ``kernels/tuning.py`` under the ``varlen`` layout key (never shared with
    padded-bucket entries).  Returns (T, Hq, D).  Differentiable in q, k, v.
    """
    T, Hq, D = q.shape
    L, Hkv, _ = k.shape
    if interpret is None:
        from repro.kernels.common import should_interpret
        interpret = should_interpret()
    compute = resolve_compute_dtype(q.dtype)
    if tq is None or tk is None:
        atq, atk = tuning.get_tiles(
            "flash", n_q=T, n_k=L, d=D, dtype=q.dtype, interpret=interpret,
            variant="plain", layout="varlen", compute=compute)
        tq = tq or atq
        tk = tk or atk
    tq, tk = min(tq, tuning.round_up(T, 8)), min(tk, tuning.round_up(L, 8))

    kb = key_padding_bias(key_valid[None] if key_valid is not None else None,
                          1, L)
    Tp, Lp = tuning.round_up(T, tq), tuning.round_up(L, tk)
    if Lp != L:
        k = jnp.pad(k, ((0, Lp - L), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, Lp - L), (0, 0), (0, 0)))
        kb = jnp.pad(kb, ((0, 0), (0, Lp - L)), constant_values=NEG_INF)
    if Tp != T:
        q = jnp.pad(q, ((0, Tp - T), (0, 0), (0, 0)))

    # positions at/after offsets[-1] (capacity + tile padding) get segment id
    # S, which matches no real sample — padded queries and keys are mutually
    # invisible to real ones by the in-kernel equality test.  Concrete
    # offsets resolve through the host-side LRU (one build per batch layout
    # instead of one per call); tracers take the jnp path inside
    qseg, kseg, qrng, krng = occupancy.cached_varlen_maps(
        q_offsets, k_offsets, Tp, Lp, tq, tk)
    occupancy.record("varlen_flash", occupancy.ranges_live_map(qrng, krng))

    out = flash_attention_varlen_kernel_call(
        _to_grouped(q[None], Hkv), _to_bh(k[None]), _to_bh(v[None]), kb,
        qseg[None], kseg[None], qrng, krng,
        tq=tq, tk=tk, interpret=interpret, compute=compute)
    out = _from_grouped(out, 1, Hkv)[0]
    return out[:T] if Tp != T else out


def ball_attention_varlen(q, k, v, offsets, mask, ball_size: int, *,
                          interpret: bool | None = None):
    """Packed-varlen Ball-Tree Attention.

    q: (T, Hq, D); k, v: (T, Hkv, D); ``offsets`` (S+1,) int32 per the
    packed contract; ``mask``: (T,) bool or None.  Because every offsets
    entry is a multiple of ``ball_size`` (``pack_varlen`` guarantees it), no
    ball straddles a sample boundary — the block-diagonal BTA kernel on the
    packed axis is already sample-isolating, so this dispatches to the
    batched kernel at B=1 with zero per-sample padding slots.  Capacity-tail
    balls are fully masked and return zeros.  Returns (T, Hq, D).
    Differentiable in q, k, v."""
    return ball_attention(q[None], k[None], v[None],
                          mask[None] if mask is not None else None,
                          ball_size, interpret=interpret)[0]


def local_window_attention_varlen(q, k, v, offsets, window: int, mask=None, *,
                                  interpret: bool | None = None):
    """Packed-varlen blocked local causal attention.

    q: (T, Hq, D); k, v: (T, Hkv, D); ``offsets`` (S+1,) int32 — every entry
    must be a multiple of ``window`` so blocks never straddle a boundary
    (``pack_varlen`` with a ball-size multiple of the window guarantees it).
    Per-BLOCK segment ids derived from ``offsets`` ride into the kernel: the
    first block of each sample sees no prev block, and a sample's last block
    leaks no gradient to the next sample (``kernels/local.py``).  ``mask``:
    (T,) bool or None.  Returns (T, Hq, D).  Differentiable in q, k, v."""
    T, Hq, D = q.shape
    Hkv = k.shape[1]
    seg = segment_ids_from_offsets(offsets, T)
    blk_seg = seg.reshape(T // window, window)[:, 0][None]     # (1, n_b)
    kb = key_padding_bias(mask[None] if mask is not None else None, 1, T)
    occupancy.record("local", _local_half_live(kb, window, blk_seg))
    out = local_window_kernel_call(
        _to_grouped(q[None], Hkv), _to_bh(k[None]), _to_bh(v[None]), kb,
        window=window, n_heads=Hkv, interpret=interpret, blk_seg=blk_seg,
        compute=resolve_compute_dtype(q.dtype))
    return _from_grouped(out, 1, Hkv)[0]


def selection_attention_varlen(q, k, v, top_idx, sel_valid, offsets, mask, *,
                               block_size: int, group_size: int,
                               interpret: bool | None = None):
    """Packed-varlen group-selected sparse attention.

    q: (T, Hq, D); k, v: (T, Hkv, D); ``top_idx``/``sel_valid``:
    (G, Hkv, k*) — selected coarse-block ids are GLOBAL packed-axis block
    indices.  Sample isolation is enforced UPSTREAM: the selection scores
    mask cross-sample (group, block) pairs to NEG_INF
    (``core.bsa._selection_scores`` with segment ids), so a selected block
    always belongs to the query group's own sample and the gather kernel
    needs no extra masking — ``offsets`` is part of the signature for
    contract uniformity (and future in-kernel verification).  ``mask``:
    (T,) bool or None masks tokens inside gathered blocks.  Returns
    (T, Hq, D).  Differentiable in q, k, v."""
    return selection_attention(
        q[None], k[None], v[None], top_idx[None], sel_valid[None],
        mask[None] if mask is not None else None,
        block_size=block_size, group_size=group_size, interpret=interpret)[0]


def paged_gather(pool, rows, *, interpret: bool | None = None,
                 force_kernel: bool = False):
    """Gather pool rows for the paged decode path.

    ``pool``: (R, Hkv, D) flat KV pool; ``rows``: int32 of any shape holding
    pool-row indices.  Returns ``rows.shape + (Hkv, D)``.

    Compiled TPU runs use the scalar-prefetch row-DMA kernel
    (``kernels/paged.py``).  Interpret mode falls back to plain advanced
    indexing UNLESS ``force_kernel``: the kernel is one grid cell per row,
    which Mosaic pipelines on hardware but the interpreter executes as
    O(rows) Python per decode step — the fallback keeps the interpret CI leg
    linear (same reasoning as ``common.interpret_batch_map``), and the
    forced path lets parity tests still execute the kernel body.
    """
    if interpret is None:
        from repro.kernels.common import should_interpret
        interpret = should_interpret()
    if interpret and not force_kernel:
        return pool[rows]
    from repro.kernels.paged import paged_gather_kernel_call
    flat = paged_gather_kernel_call(pool, rows.reshape(-1).astype(jnp.int32),
                                    interpret=interpret)
    return flat.reshape(*rows.shape, *pool.shape[1:])


def gated_combine(outs, gates, mask, *, interpret: bool | None = None):
    """Fused gate-and-mask epilogue over the three branch outputs.

    ``outs``: three (B, N, H, D) arrays (same shape/dtype); ``gates``: three
    fp32 arrays broadcastable to (B, N, H, 1) — scalar-mode (1, 1, H, 1) or
    token-mode (B, N, H, 1) sigmoid gate values; ``mask``: (B, N) bool
    (True = real query) or None.  Computes
    ``(Σ_b g_b · out_b) · mask`` in one Pallas pass instead of three fp32
    HBM temporaries.  Returns (B, N, H, D) in ``outs[0].dtype``.
    Differentiable in outs and gates (gate cotangents flow back through the
    jnp broadcast, so scalar gates receive their summed gradient)."""
    o1, o2, o3 = outs
    B, N, H, D = o1.shape
    R = B * N * H
    g1, g2, g3 = (jnp.broadcast_to(g.astype(jnp.float32), (B, N, H, 1))
                  .reshape(R, 1) for g in gates)
    if mask is None:
        m = jnp.ones((R, 1), jnp.float32)
    else:
        m = (jnp.broadcast_to(mask[:, :, None], (B, N, H))
             .reshape(R, 1).astype(jnp.float32))
    rows = [o.reshape(R, D) for o in (o1, o2, o3)]

    tile = tuning.heuristic_tile(R, 1024)
    Rp = tuning.round_up(R, tile)
    if Rp != R:
        pad = ((0, Rp - R), (0, 0))
        rows = [jnp.pad(o, pad) for o in rows]
        g1, g2, g3 = (jnp.pad(g, pad) for g in (g1, g2, g3))
        m = jnp.pad(m, pad)
    out = gated_combine_kernel_call(rows[0], rows[1], rows[2], g1, g2, g3, m,
                                    tile=tile, interpret=interpret)
    if Rp != R:
        out = out[:R]
    return out.reshape(B, N, H, D)
