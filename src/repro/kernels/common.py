"""Shared Pallas kernel utilities.

TPU is the TARGET; on this CPU container kernels run under interpret mode
(``interpret=True`` executes the kernel body in Python for correctness).
``should_interpret()`` auto-detects; set REPRO_PALLAS_INTERPRET=0/1 to force.

All four kernels are differentiable via ``jax.custom_vjp``: the forward
kernels emit a per-query-row logsumexp residual (``lse = m + log l``) and the
backward kernels recompute the attention probabilities per tile as
``p = exp(s − lse)`` (FlashAttention-style recomputation — O(N) residual
memory instead of materialising p).  ``lse_finalize`` / ``p_from_lse`` keep
the two sides of that contract in one place.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.numerics import NEG_INF  # noqa: F401 — shared constant, re-exported
                                    # for the kernel modules

# Sentinel logsumexp for query rows with NO valid key (fully-masked ball /
# all-invalid selection group): exp(s − LSE_EMPTY) underflows to exactly 0
# for any finite logit s, so backward recomputation yields p ≡ 0 for the row.
LSE_EMPTY = 1e30


def should_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def lse_finalize(m_safe, l):
    """Per-row logsumexp residual from running max/sum.  (rows, 1) fp32.

    ``l ≥ 1`` whenever any key is valid (the max term contributes exp(0)=1),
    so ``lse ≥ m ≥ s`` and backward ``exp(s − lse) ≤ 1`` never overflows.
    """
    return jnp.where(l > 0.0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), LSE_EMPTY)


def p_from_lse(s, lse):
    """Recompute normalised attention probabilities from logits + residual."""
    p = jnp.exp(s - lse)
    return jnp.where(s <= NEG_INF / 2, 0.0, p)


def interpret_batch_map(fn, *args):
    """Sequential ``lax.map`` of a kernel call over leading-dim slices.

    INTERPRET-MODE ONLY.  The Pallas interpreter's per-grid-cell cost grows
    with the TOTAL operand size, so a batched grid costs O(B²) on CPU —
    mapping per-sample slices keeps it linear while staying one jitted
    computation (and differentiable: scan-of-custom_vjp).  Compiled TPU runs
    never take this path; there the batched grid is the whole point.
    """
    return jax.lax.map(lambda t: fn(*[a[None] for a in t])[0], args)
