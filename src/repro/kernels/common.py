"""Shared Pallas kernel utilities.

TPU is the TARGET; on this CPU container kernels run under interpret mode
(``interpret=True`` executes the kernel body in Python for correctness).
``should_interpret()`` auto-detects; set REPRO_PALLAS_INTERPRET=0/1 to force.
"""

from __future__ import annotations

import os

import jax

NEG_INF = -1e30


def should_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"
