"""Shared Pallas kernel utilities.

TPU is the TARGET; on this CPU container kernels run under interpret mode
(``interpret=True`` executes the kernel body in Python for correctness).
``should_interpret()`` auto-detects; set REPRO_PALLAS_INTERPRET=0/1 to force.

All four kernels are differentiable via ``jax.custom_vjp``: the forward
kernels emit a per-query-row logsumexp residual (``lse = m + log l``) and the
backward kernels recompute the attention probabilities per tile as
``p = exp(s − lse)`` (FlashAttention-style recomputation — O(N) residual
memory instead of materialising p).  ``lse_finalize`` / ``p_from_lse`` keep
the two sides of that contract in one place.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.numerics import NEG_INF  # noqa: F401 — shared constant, re-exported
                                    # for the kernel modules

# Sentinel logsumexp for query rows with NO valid key (fully-masked ball /
# all-invalid selection group): exp(s − LSE_EMPTY) underflows to exactly 0
# for any finite logit s, so backward recomputation yields p ≡ 0 for the row.
LSE_EMPTY = 1e30


def fp8_enabled() -> bool:
    """Opt-in fp8 QK^T experiment (REPRO_FP8=1).  Off by default."""
    return os.environ.get("REPRO_FP8", "") not in ("", "0", "false", "False")


def resolve_compute_dtype(dtype) -> str:
    """Input dtype → canonical MATMUL-OPERAND dtype name for the kernels.

    The kernel-level precision contract (docs/architecture.md):

      * fp32/fp64 inputs compute in fp32 — bit-identical to the historical
        force-upcast behaviour;
      * sub-fp32 inputs (bf16/fp16) keep their storage dtype as the matmul
        operand dtype — Q/K/V tiles stay bf16 through QK^T and PV — while
        every ``dot_general`` accumulates fp32 (``preferred_element_type``)
        and all softmax statistics / lse / scratch stay fp32;
      * with REPRO_FP8=1, sub-fp32 inputs use float8_e4m3fn for the QK^T
        OPERANDS only (the experiment); non-QK matmuls stay ≥ 16-bit via
        ``mma_dtype``.

    Returns a canonical dtype NAME (hashable, cache-key friendly).
    """
    d = jnp.dtype(dtype)
    if d.itemsize >= 4:
        return "float32"
    if fp8_enabled() and hasattr(jnp, "float8_e4m3fn"):
        return "float8_e4m3fn"
    return d.name


def mma_dtype(compute: str) -> str:
    """Operand dtype for the non-QK^T matmuls (PV, dP, dQ, dK, dV).

    fp8 is a QK^T-only experiment: everything else never drops below
    16 bits, so gradients and the PV contraction keep bf16 operands."""
    return "bfloat16" if jnp.dtype(compute).itemsize == 1 else compute


def should_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def lse_finalize(m_safe, l):
    """Per-row logsumexp residual from running max/sum.  (rows, 1) fp32.

    ``l ≥ 1`` whenever any key is valid (the max term contributes exp(0)=1),
    so ``lse ≥ m ≥ s`` and backward ``exp(s − lse) ≤ 1`` never overflows.
    """
    return jnp.where(l > 0.0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), LSE_EMPTY)


def p_from_lse(s, lse):
    """Recompute normalised attention probabilities from logits + residual."""
    p = jnp.exp(s - lse)
    return jnp.where(s <= NEG_INF / 2, 0.0, p)


def interpret_batch_map(fn, *args):
    """Sequential ``lax.map`` of a kernel call over leading-dim slices.

    INTERPRET-MODE ONLY.  The Pallas interpreter's per-grid-cell cost grows
    with the TOTAL operand size, so a batched grid costs O(B²) on CPU —
    mapping per-sample slices keeps it linear while staying one jitted
    computation (and differentiable: scan-of-custom_vjp).  Compiled TPU runs
    never take this path; there the batched grid is the whole point.
    """
    return jax.lax.map(lambda t: fn(*[a[None] for a in t])[0], args)
