"""Flash-attention Pallas kernel with the mask modes BSA needs.

Streaming softmax over K/V tiles (running max / sum / accumulator in VMEM
scratch).  Used for:

  * the COMPRESSION branch — queries vs φ-pooled coarse KV.  ``block_causal``
    (with ``ell`` = compression block length) masks coarse block j for query
    t unless the block ends strictly before t: (j+1)·ell − 1 < t.  The mask
    is generated in-kernel from indices, never materialised (an N × N/ℓ fp32
    bias for 32k tokens would be 0.5 GB — this is why the bias is virtual).
  * FULL attention baseline — ``causal`` token mask.
  * both support an additive per-key bias row (B, L) fp32 for padding.

Grid: (BH, nQ, nK) with K innermost.  Scratch: m, l: (Tq, 1) fp32,
acc: (Tq, D) fp32.  VMEM @ Tq=Tk=256, D=128 ≈ 0.6 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, should_interpret

__all__ = ["flash_attention_kernel_call"]


def _pick_tile(n: int, pref: int) -> int:
    """Largest divisor of n that is ≤ pref (tile sizes must divide the axis)."""
    t = min(pref, n)
    while n % t:
        t -= 1
    return t


def _kernel(q_ref, k_ref, v_ref, kbias_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, n_k: int, tq: int, tk: int,
            causal: bool, block_causal: bool, ell: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (Tq, D)
    k = k_ref[0].astype(jnp.float32)                       # (Tk, D)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + kbias_ref[0]                                   # (Tk,) key-validity bias

    if causal or block_causal:
        qpos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kidx = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        if block_causal:
            ok = (kidx + 1) * ell - 1 < qpos               # coarse block ends before t
        else:
            ok = kidx <= qpos
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                    # (Tq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.maximum(m_new, NEG_INF / 2)
    p = jnp.exp(s - m_safe)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "n_heads", "tq", "tk", "causal", "block_causal", "ell", "interpret"))
def flash_attention_kernel_call(q, k, v, key_bias, *, n_heads: int,
                                tq: int = 256, tk: int = 256,
                                causal: bool = False, block_causal: bool = False,
                                ell: int = 1, interpret: bool | None = None):
    """q: (BH, N, D); k,v: (BH, L, D); key_bias: (B, L) fp32 additive."""
    BH, N, D = q.shape
    L = k.shape[1]
    tq = _pick_tile(N, tq)
    tk = _pick_tile(L, tk)
    H = n_heads
    if interpret is None:
        interpret = should_interpret()
    n_k = L // tk

    grid = (BH, N // tq, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (D ** 0.5), n_k=n_k, tq=tq,
                          tk=tk, causal=causal, block_causal=block_causal,
                          ell=ell),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, i, j: (b // H, j)),
        ],
        out_specs=pl.BlockSpec((1, tq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, N, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, key_bias)
