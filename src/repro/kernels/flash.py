"""Flash-attention Pallas kernel with the mask modes BSA needs.

Streaming softmax over K/V tiles (running max / sum / accumulator in VMEM
scratch).  Used for:

  * the COMPRESSION branch — queries vs φ-pooled coarse KV.  ``block_causal``
    (with ``ell`` = compression block length) masks coarse block j for query
    t unless the block ends strictly before t: (j+1)·ell − 1 < t.  The mask
    is generated in-kernel from indices and never materialised (an N × N/ℓ
    fp32 bias for 32k tokens would be 0.5 GB — this is why the bias is
    virtual).
  * FULL attention baseline — ``causal`` token mask.
  * both support an additive per-key bias row (B, L) fp32 for padding.

GQA-NATIVE: the grid iterates KV heads.  Queries arrive as
(B·Hkv, rep, N, D); each grid cell loads ONE (Tk, D) K/V tile and streams it
against the (rep·Tq, D) fused query rows of its GQA group — K/V HBM traffic
is divided by ``rep`` versus the head-repeated layout, and the rep× taller
matmul keeps the MXU fed.  Tile sizes (tq, tk) come from the caller
(``kernels/ops.py`` resolves them via the ``kernels/tuning.py`` autotuner
and PADS both axes to tile multiples, so arbitrary N/L are legal here as
long as tq | N and tk | L).

TILE-OCCUPANCY SKIPPING (``kernels/occupancy.py``): a host-precomputed
(B, nQ, nK) int32 liveness map rides in as a SCALAR-PREFETCH operand
(``pltpu.PrefetchScalarGridSpec``); ``pl.when(live)`` wraps the tile body in
the forward AND both backward kernels, so a grid cell whose key tile is all
masked / whose query tile is all padding / that the causal structure rules
out issues no matmuls at all.  Init and finalize stay unconditional: a query
tile none of whose cells were live finalizes to zeros with lse = LSE_EMPTY —
exactly what the jnp oracle produces for all-masked rows, so skipping is
bit-exact (outputs and gradients).

PRECISION CONTRACT (``common.resolve_compute_dtype``): operand tiles are
cast to the compute dtype — fp32 inputs compute fp32 (the historical
behaviour), bf16 inputs stay bf16 through QK^T and PV, fp8 (REPRO_FP8=1)
applies to the QK^T operands only — while every ``dot_general`` accumulates
fp32 via ``preferred_element_type`` and softmax statistics / lse / scratch
are always fp32.

Grid: (B·Hkv, nQ, nK) with K innermost.  Scratch: m, l: (rep·Tq, 1) fp32,
acc: (rep·Tq, D) fp32.  VMEM @ rep=4, Tq=Tk=256, D=128 ≈ 1.7 MiB.

Differentiable (FlashAttention-style recomputation backward): the forward
additionally emits per-row logsumexp (B·Hkv, rep, N); the backward
recomputes p = exp(s − lse) per tile in two kernels — a dQ kernel on the
forward grid (K innermost, dQ accumulated in scratch) and a dK/dV kernel on
the transposed grid (B·Hkv, nK, nQ) with Q innermost; dK/dV of a tile
accumulate over the group's rep query heads inside the (rep·Tq)-row
contraction itself, so each gradient stays a pure per-tile accumulation
with no cross-grid races.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, interpret_batch_map, lse_finalize,
                                  mma_dtype, p_from_lse, resolve_compute_dtype,
                                  should_interpret)

__all__ = ["flash_attention_kernel_call"]


def _mask_logits(s, i, j, *, rows, tq, tk, causal, block_causal, ell):
    """Apply the virtual (index-generated) causal / block-causal mask.

    ``rows = rep·tq``: row r of the fused group tile is query position
    ``i·tq + r % tq`` (rep-major layout), so all rep heads of a group see
    the same mask row."""
    if not (causal or block_causal):
        return s
    qpos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (rows, tk), 0) % tq
    kidx = j * tk + jax.lax.broadcasted_iota(jnp.int32, (rows, tk), 1)
    if block_causal:
        ok = (kidx + 1) * ell - 1 < qpos                   # coarse block ends before t
    else:
        ok = kidx <= qpos
    return jnp.where(ok, s, NEG_INF)


def _fwd_kernel(live_ref, q_ref, k_ref, v_ref, kbias_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                scale: float, n_k: int, tq: int, tk: int,
                causal: bool, block_causal: bool, ell: int,
                nh: int, compute: str):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    rep, _, D = q_ref.shape[1:]
    rows = rep * tq
    sdt = jnp.dtype(compute)                               # QK^T operand dtype
    adt = jnp.dtype(mma_dtype(compute))                    # PV operand dtype

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live_ref[b // nh, i, j] != 0)
    def _step():
        q = q_ref[0].astype(sdt).reshape(rows, D)          # (rep·Tq, D)
        k = k_ref[0].astype(sdt)                           # (Tk, D)
        v = v_ref[0].astype(adt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + kbias_ref[0]                               # (Tk,) key-validity bias
        s = _mask_logits(s, i, j, rows=rows, tq=tq, tk=tk, causal=causal,
                         block_causal=block_causal, ell=ell)

        m_prev = m_scr[...]                                # (rep·Tq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(adt), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(j == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom).reshape(rep, tq, D).astype(o_ref.dtype)
        m_safe_f = jnp.maximum(m_scr[...], NEG_INF / 2)
        lse_ref[0] = lse_finalize(m_safe_f, l_scr[...])[:, 0].reshape(rep, tq)


def _dq_kernel(live_ref, q_ref, k_ref, v_ref, kbias_ref, do_ref, lse_ref,
               delta_ref, dq_ref, dq_scr, *,
               scale: float, n_k: int, tq: int, tk: int,
               causal: bool, block_causal: bool, ell: int,
               nh: int, compute: str):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    rep, _, D = q_ref.shape[1:]
    rows = rep * tq
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(live_ref[b // nh, i, j] != 0)
    def _step():
        q = q_ref[0].astype(sdt).reshape(rows, D)          # (rep·Tq, D)
        k = k_ref[0].astype(sdt)                           # (Tk, D)
        ka = k_ref[0].astype(adt)
        v = v_ref[0].astype(adt)
        do = do_ref[0].astype(adt).reshape(rows, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + kbias_ref[0]
        s = _mask_logits(s, i, j, rows=rows, tq=tq, tk=tk, causal=causal,
                         block_causal=block_causal, ell=ell)
        p = p_from_lse(s, lse_ref[0].reshape(rows, 1))     # (rep·Tq, Tk)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0].reshape(rows, 1)) * scale
        dq_scr[...] += jax.lax.dot_general(ds.astype(adt), ka,
                                           (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].reshape(rep, tq, D).astype(dq_ref.dtype)


def _dkv_kernel(live_ref, q_ref, k_ref, v_ref, kbias_ref, do_ref, lse_ref,
                delta_ref, dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale: float, n_q: int, tq: int, tk: int,
                causal: bool, block_causal: bool, ell: int,
                nh: int, compute: str):
    b = pl.program_id(0)
    j = pl.program_id(1)                                   # K tile (outer)
    i = pl.program_id(2)                                   # Q tile (inner)
    rep, _, D = q_ref.shape[1:]
    rows = rep * tq
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(live_ref[b // nh, i, j] != 0)
    def _step():
        q = q_ref[0].astype(sdt).reshape(rows, D)          # (rep·Tq, D)
        qa = q_ref[0].astype(adt).reshape(rows, D)
        k = k_ref[0].astype(sdt)                           # (Tk, D)
        v = v_ref[0].astype(adt)
        do = do_ref[0].astype(adt).reshape(rows, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + kbias_ref[0]
        s = _mask_logits(s, i, j, rows=rows, tq=tq, tk=tk, causal=causal,
                         block_causal=block_causal, ell=ell)
        p = p_from_lse(s, lse_ref[0].reshape(rows, 1))     # (rep·Tq, Tk)
        # the (0,)-axis contraction sums over rep·Tq rows: the GQA group's
        # dK/dV accumulation happens inside the matmul
        dv_scr[...] += jax.lax.dot_general(p.astype(adt), do,
                                           (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0].reshape(rows, 1)) * scale
        dk_scr[...] += jax.lax.dot_general(ds.astype(adt), qa,
                                           (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(i == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _fwd_call(q, k, v, key_bias, live, *, n_heads, tq, tk, causal,
              block_causal, ell, interpret, compute):
    BH, rep, N, D = q.shape
    L = k.shape[1]
    n_k = L // tk
    kern = functools.partial(_fwd_kernel, scale=1.0 / (D ** 0.5), n_k=n_k,
                             tq=tq, tk=tk, causal=causal,
                             block_causal=block_causal, ell=ell,
                             nh=n_heads, compute=compute)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, N // tq, n_k),
        in_specs=[
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, lv: (b, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, lv: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, lv: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, i, j, lv: (b // n_heads, j)),
        ],
        out_specs=(pl.BlockSpec((1, rep, tq, D), lambda b, i, j, lv: (b, 0, i, 0)),
                   pl.BlockSpec((1, rep, tq), lambda b, i, j, lv: (b, 0, i))),
        scratch_shapes=[
            pltpu.VMEM((rep * tq, 1), jnp.float32),
            pltpu.VMEM((rep * tq, 1), jnp.float32),
            pltpu.VMEM((rep * tq, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, rep, N), jnp.float32)),
        interpret=interpret,
    )(live, q, k, v, key_bias)


def _bwd_calls(q, k, v, key_bias, live, do, lse, delta, *, n_heads, tq, tk,
               causal, block_causal, ell, interpret, compute):
    BH, rep, N, D = q.shape
    L = k.shape[1]
    H = n_heads
    n_q, n_k = N // tq, L // tk
    mask_kw = dict(scale=1.0 / (D ** 0.5), tq=tq, tk=tk, causal=causal,
                   block_causal=block_causal, ell=ell, nh=H, compute=compute)

    dq_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, lv: (b, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, lv: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j, lv: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, i, j, lv: (b // H, j)),
            pl.BlockSpec((1, rep, tq, D), lambda b, i, j, lv: (b, 0, i, 0)),
            pl.BlockSpec((1, rep, tq), lambda b, i, j, lv: (b, 0, i)),
            pl.BlockSpec((1, rep, tq), lambda b, i, j, lv: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, rep, tq, D),
                               lambda b, i, j, lv: (b, 0, i, 0)),
        scratch_shapes=[pltpu.VMEM((rep * tq, D), jnp.float32)],
    )
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, n_k=n_k, **mask_kw),
        grid_spec=dq_spec,
        out_shape=jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
        interpret=interpret,
    )(live, q, k, v, key_bias, do, lse, delta)

    dkv_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, rep, tq, D), lambda b, j, i, lv: (b, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, j, i, lv: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, j, i, lv: (b, j, 0)),
            pl.BlockSpec((1, tk), lambda b, j, i, lv: (b // H, j)),
            pl.BlockSpec((1, rep, tq, D), lambda b, j, i, lv: (b, 0, i, 0)),
            pl.BlockSpec((1, rep, tq), lambda b, j, i, lv: (b, 0, i)),
            pl.BlockSpec((1, rep, tq), lambda b, j, i, lv: (b, 0, i)),
        ],
        out_specs=(pl.BlockSpec((1, tk, D), lambda b, j, i, lv: (b, j, 0)),
                   pl.BlockSpec((1, tk, D), lambda b, j, i, lv: (b, j, 0))),
        scratch_shapes=[pltpu.VMEM((tk, D), jnp.float32),
                        pltpu.VMEM((tk, D), jnp.float32)],
    )
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, n_q=n_q, **mask_kw),
        grid_spec=dkv_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, L, D), k.dtype),
                   jax.ShapeDtypeStruct((BH, L, D), v.dtype)),
        interpret=interpret,
    )(live, q, k, v, key_bias, do, lse, delta)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_vjp(n_heads: int, tq: int, tk: int, causal: bool, block_causal: bool,
              ell: int, interpret: bool, compute: str):
    kw = dict(n_heads=n_heads, tq=tq, tk=tk, causal=causal,
              block_causal=block_causal, ell=ell, interpret=interpret,
              compute=compute)

    @jax.custom_vjp
    def attend(q, k, v, key_bias, live):
        return _fwd_call(q, k, v, key_bias, live, **kw)[0]

    def attend_fwd(q, k, v, key_bias, live):
        o, lse = _fwd_call(q, k, v, key_bias, live, **kw)
        return o, (q, k, v, key_bias, live, o, lse)

    def attend_bwd(res, do):
        q, k, v, key_bias, live, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        dq, dk, dv = _bwd_calls(q, k, v, key_bias, live, do, lse, delta, **kw)
        return dq, dk, dv, None, None                      # bias/liveness: no grad

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


@functools.partial(jax.jit, static_argnames=(
    "n_heads", "tq", "tk", "causal", "block_causal", "ell", "interpret",
    "compute"))
def flash_attention_kernel_call(q, k, v, key_bias, live=None, *, n_heads: int,
                                tq: int = 256, tk: int = 256,
                                causal: bool = False, block_causal: bool = False,
                                ell: int = 1, interpret: bool | None = None,
                                compute: str | None = None):
    """q: (B·Hkv, rep, N, D) grouped queries; k, v: (B·Hkv, L, D) — one K/V
    stream per KV head shared by its rep query heads; key_bias: (B, L) fp32
    additive; ``live``: optional (B, N/tq, L/tk) int32 tile-liveness map
    (``occupancy.flash_live_map``; None = all live); ``n_heads`` is the KV
    head count Hkv.  ``tq`` must divide N and ``tk`` divide L
    (``kernels/ops.py`` pads both axes to guarantee this).  ``compute`` is
    the canonical matmul-operand dtype name (None resolves from q.dtype —
    see ``common.resolve_compute_dtype``; callers that toggle REPRO_FP8
    between calls should pass it explicitly, since this wrapper is jitted).
    Returns (B·Hkv, rep, N, D).  Differentiable in q, k, v."""
    BH, rep, N, D = q.shape
    L = k.shape[1]
    tq = min(tq, N)
    tk = min(tk, L)
    if N % tq or L % tk:
        # a real error, not an assert: under python -O a silently truncated
        # grid would leave the tail query rows of the output unwritten
        raise ValueError(f"tiles must divide the (padded) axes: N={N} tq={tq},"
                         f" L={L} tk={tk} — kernels/ops.flash_attention pads;"
                         " direct callers must pass dividing tiles")
    if interpret is None:
        interpret = should_interpret()
    if compute is None:
        compute = resolve_compute_dtype(q.dtype)
    if live is None:
        live = jnp.ones((key_bias.shape[0], N // tq, L // tk), jnp.int32)
    if interpret and BH > 1:
        # CPU fallback: per-slice grids keep the interpreter linear in B·Hkv
        bias_bh = jnp.repeat(key_bias, n_heads, axis=0)
        live_bh = jnp.repeat(live, n_heads, axis=0)
        return interpret_batch_map(
            _make_vjp(1, tq, tk, causal, block_causal, ell, True, compute),
            q, k, v, bias_bh, live_bh)
    return _make_vjp(n_heads, tq, tk, causal, block_causal, ell, interpret,
                     compute)(q, k, v, key_bias, live)
