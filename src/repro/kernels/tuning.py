"""Tile autotuner for the Pallas kernels (+ the shared tile heuristic).

Tile-shape choice dominates sparse-kernel throughput (Block Sparse Flash
Attention's headline result), so instead of a fixed divisor rule the flash
kernel's ``(tq, tk)`` tiles come from a three-stage policy:

  1. **Cache hit** — a JSON cache persisted at ``~/.cache/repro/tuning.json``
     (override with ``$REPRO_TUNING_CACHE``) keyed by
     ``(kernel, shape-bucket, head_dim, dtype, interpret|compiled)``.  Shape
     buckets are next-power-of-two, so one measurement covers a band of
     ragged lengths.  A hit never re-measures — the second run of any shape
     is pure lookup.
  2. **Measured** — when autotuning is enabled (``$REPRO_AUTOTUNE=1`` or the
     ``--autotune`` flag of ``benchmarks/perf_iter.py``), the candidate grid
     is swept with timed compiled runs of the real kernel at the bucketed
     shape and the winner is persisted.  Measurement happens at trace time
     on concrete throwaway inputs (the Triton-autotune pattern), so jitted
     callers pay it once per bucket, ever.
  3. **Heuristic fallback** — otherwise :func:`heuristic_tile`, a
     deterministic rule that never degenerates: tiles are clamped to
     ``[pref // 2, pref]`` and callers PAD the axis up to a tile multiple
     (see ``kernels/ops.py``) instead of shrinking the tile to a tiny
     divisor.  Interpret mode (CI) always lands here unless a cache entry
     already exists, so CI stays fast and deterministic.

The kernel wrappers own the padding contract that makes non-divisor tiles
legal: padded KEYS are masked with ``NEG_INF`` bias (zero contribution and
exactly zero gradient), padded QUERY rows are computed and sliced off.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path

__all__ = [
    "ENV_CACHE",
    "ENV_AUTOTUNE",
    "DEFAULT_CACHE",
    "autotune_enabled",
    "cache_path",
    "clear_memory_cache",
    "heuristic_tile",
    "round_up",
    "shape_bucket",
    "flash_candidates",
    "flash_variant",
    "get_tiles",
    "tune_measure_flash",
    "tune_flash",
]

ENV_CACHE = "REPRO_TUNING_CACHE"
ENV_AUTOTUNE = "REPRO_AUTOTUNE"
DEFAULT_CACHE = "~/.cache/repro/tuning.json"

# In-memory mirror of the JSON file: {path: {key: record}}.  Keyed by path so
# tests pointing $REPRO_TUNING_CACHE at a tmpdir never see stale state.
_MEM: dict[str, dict] = {}


def autotune_enabled() -> bool:
    return os.environ.get(ENV_AUTOTUNE, "") not in ("", "0", "false", "False")


def cache_path() -> Path:
    return Path(os.environ.get(ENV_CACHE) or DEFAULT_CACHE).expanduser()


def clear_memory_cache() -> None:
    """Drop the in-memory mirror (tests; the JSON file is untouched)."""
    _MEM.clear()


def _load() -> dict:
    p = cache_path()
    key = str(p)
    if key not in _MEM:
        try:
            _MEM[key] = json.loads(p.read_text())
        except (OSError, ValueError):
            _MEM[key] = {}
    return _MEM[key]


def _save(cache: dict) -> None:
    p = cache_path()
    _MEM[str(p)] = cache
    try:
        p.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(p.parent), suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, p)                    # atomic: concurrent runs race safely
    except OSError:
        pass                                  # read-only FS: in-memory cache still works


# ---------------------------------------------------------------------------
# Deterministic heuristic (the no-measurement path)
# ---------------------------------------------------------------------------

def round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def shape_bucket(n: int) -> int:
    """Next power of two ≥ n — one cache entry per band of ragged lengths."""
    return 1 << max(n - 1, 1).bit_length() if n > 1 else 1


def heuristic_tile(n: int, pref: int) -> int:
    """Tile for an axis of length ``n`` with preference ``pref``.

    Never degenerates: the result is a multiple of 8 (TPU sublane) in
    ``[min(n', pref) // 2, pref]``.  When the tile does not divide ``n`` the
    CALLER pads the axis up to a multiple (``kernels/ops.py``) — the old rule
    of shrinking to the largest divisor collapsed to tile size 1 on prime-ish
    lengths (e.g. ragged ``bucket_length`` leftovers), serialising the grid.
    """
    if n <= pref:
        return round_up(n, 8)                 # single tile, ≤ 7 padded rows
    if n % pref == 0:
        return pref
    best = pref
    for t in range(pref, pref // 2, -8):      # sublane-aligned divisor search
        if n % t == 0:
            return t
        if round_up(n, t) - n < round_up(n, best) - n:
            best = t                          # least padding among candidates
    return best


# ---------------------------------------------------------------------------
# The cache + measurement policy
# ---------------------------------------------------------------------------

def _key(kernel: str, *, n_q: int, n_k: int, d: int, dtype, interpret: bool,
         variant: str = "", layout: str = "", compute: str = "") -> str:
    mode = "interpret" if interpret else "compiled"
    v = f"/{variant}" if variant else ""
    lay = f"/{layout}" if layout else ""
    # compute = the matmul-OPERAND dtype of the precision contract.  It joins
    # the key only when it differs from the storage dtype's own resolution,
    # so pre-contract cache entries stay valid for the default path.
    cmp_ = f"/c:{compute}" if compute and compute != "float32" else ""
    return (f"{kernel}/q{shape_bucket(n_q)}_k{shape_bucket(n_k)}_d{d}"
            f"/{str(dtype)}/{mode}{v}{lay}{cmp_}")


def flash_variant(causal: bool, block_causal: bool, ell: int) -> str:
    """Cache-key component for the flash mask mode — different in-kernel
    masking does different work, so tiles are tuned per mode."""
    if causal:
        return "causal"
    if block_causal:
        return f"blockcausal{ell}"
    return "plain"


def flash_candidates(n_q: int, n_k: int) -> list[tuple[int, int]]:
    """Candidate (tq, tk) grid (tiles ≤ the pow2 shape buckets, which they
    therefore divide exactly — measurement happens at the bucketed shape)."""
    bq, bk = shape_bucket(n_q), shape_bucket(n_k)
    cands = [(tq, tk)
             for tq in (64, 128, 256, 512) if tq <= bq
             for tk in (128, 256, 512) if tk <= bk]
    return cands or [(heuristic_tile(n_q, 256), heuristic_tile(n_k, 256))]


def get_tiles(kernel: str, *, n_q: int, n_k: int, d: int, dtype,
              interpret: bool, measure=None, variant: str = "",
              layout: str = "", compute: str = "",
              prefs: tuple[int, int] = (256, 256)) -> tuple[int, int]:
    """Resolve (tq, tk) for one kernel launch.

    ``variant`` distinguishes configurations of one kernel whose in-kernel
    work differs (flash mask modes) so they never share a cache entry.
    ``layout`` distinguishes the batch layout — "" for padded-bucket
    (B, L) batches vs ``"varlen"`` for the packed-offsets layout, whose
    per-tile segment masking / tile skipping changes the cost profile, so a
    tile measured on one layout must never be replayed on the other.
    ``compute`` is the matmul-operand dtype of the precision contract
    (``common.resolve_compute_dtype``) — a tile tuned under bf16 or fp8
    operands is never replayed for fp32 compute, and vice versa.
    ``measure(tq, tk) -> seconds`` is invoked per candidate ONLY on a cache
    miss with autotuning enabled; the winner is persisted.  Without a measure
    callback (or with autotune off / measure failure) the deterministic
    heuristic is returned and nothing is written.
    """
    key = _key(kernel, n_q=n_q, n_k=n_k, d=d, dtype=dtype, interpret=interpret,
               variant=variant, layout=layout, compute=compute)
    cache = _load()
    hit = cache.get(key)
    if hit:
        return tuple(hit["tiles"])
    fallback = (heuristic_tile(n_q, prefs[0]), heuristic_tile(n_k, prefs[1]))
    if measure is None or not autotune_enabled():
        return fallback
    timings = {}
    for tq, tk in flash_candidates(n_q, n_k):
        try:
            timings[(tq, tk)] = float(measure(tq, tk))
        except Exception:                     # candidate OOM/unsupported: skip
            continue
    if not timings:
        return fallback
    best = min(timings, key=timings.get)
    cache[key] = {"tiles": list(best), "us": round(timings[best] * 1e6, 1),
                  "candidates": {f"{a}x{b}": round(t * 1e6, 1)
                                 for (a, b), t in sorted(timings.items())},
                  "measured_at": time.strftime("%Y-%m-%dT%H:%M:%S")}
    _save(cache)
    return best


def tune_measure_flash(tq: int, tk: int, *, n_q: int, n_k: int, d: int, dtype,
                       interpret: bool, causal: bool = False,
                       block_causal: bool = False, ell: int = 1,
                       bh: int = 2, iters: int = 3) -> float:
    """Time one (tq, tk) candidate of the flash kernel, in seconds.

    Builds throwaway inputs at the BUCKETED shape (so the measurement is
    valid for the whole cache band) and times the real
    ``flash_attention_kernel_call`` — median of ``iters`` after one
    compile/warmup call.  Runs eagerly on concrete data, so it is safe to
    invoke from a traced caller (the Triton-autotune pattern).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.flash import flash_attention_kernel_call

    bq, bk = shape_bucket(n_q), shape_bucket(n_k)
    nq, nk = round_up(bq, tq), round_up(bk, tk)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (bh, 1, nq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (bh, nk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (bh, nk, d), jnp.float32).astype(dtype)
    bias = jnp.zeros((1, nk), jnp.float32)

    def run():
        return flash_attention_kernel_call(
            q, k, v, bias, n_heads=bh, causal=causal,
            block_causal=block_causal, ell=ell, tq=tq, tk=tk,
            interpret=interpret)

    jax.block_until_ready(run())              # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def tune_flash(*, n_q: int, n_k: int, d: int, dtype, interpret: bool,
               bh: int = 2, causal: bool = False, block_causal: bool = False,
               ell: int = 1, iters: int = 3) -> tuple[int, int]:
    """Measure + persist the flash kernel's tiles for one shape bucket.

    Honours the cache: a hit returns immediately without measuring, which is
    what makes a second ``--autotune`` run measurement-free.
    """
    def measure(tq, tk):
        return tune_measure_flash(tq, tk, n_q=n_q, n_k=n_k, d=d, dtype=dtype,
                                  interpret=interpret, causal=causal,
                                  block_causal=block_causal, ell=ell, bh=bh,
                                  iters=iters)

    return get_tiles("flash", n_q=n_q, n_k=n_k, d=d, dtype=dtype,
                     interpret=interpret, measure=measure,
                     variant=flash_variant(causal, block_causal, ell))
