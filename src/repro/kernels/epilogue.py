"""Fused gated-combine epilogue Pallas kernel.

``bsa_attention`` / ``nsa_causal_attention`` end by sigmoid-gating their
three branch outputs and masking padded queries.  Composed in jnp that is
three fp32 upcast temporaries + three multiplies + two adds + a select —
seven HBM round-trips over (B, N, H, D) data.  This kernel does the whole
epilogue in ONE pass:

    out = (g_ball·o_ball + g_cmp·o_cmp + g_slc·o_slc) · m

Layout: branch outputs are flattened to rows (R, D) with R = B·N·H; gates
and the query-validity mask become per-row (R, 1) fp32 columns (the
broadcast over D happens in-register).  Purely elementwise → VPU work, grid
over row tiles.  The row tile is chosen by the wrapper (``kernels/ops.py``),
which pads R up to a tile multiple and slices the pad off after.

Differentiable in the branch outputs AND the gates (gates are parameters):
    d_o_b = g_b · m · do              d_g_b = m · Σ_D(do · o_b)
computed by a second elementwise kernel on the same grid.  The mask is a
mask — its cotangent is dropped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import should_interpret

__all__ = ["gated_combine_kernel_call"]


def _fwd_kernel(o1_ref, o2_ref, o3_ref, g1_ref, g2_ref, g3_ref, m_ref, out_ref):
    acc = (g1_ref[...] * o1_ref[...].astype(jnp.float32)
           + g2_ref[...] * o2_ref[...].astype(jnp.float32)
           + g3_ref[...] * o3_ref[...].astype(jnp.float32))
    out_ref[...] = (acc * m_ref[...]).astype(out_ref.dtype)


def _bwd_kernel(o1_ref, o2_ref, o3_ref, g1_ref, g2_ref, g3_ref, m_ref, do_ref,
                do1_ref, do2_ref, do3_ref, dg1_ref, dg2_ref, dg3_ref):
    do = do_ref[...].astype(jnp.float32) * m_ref[...]      # (t, D) masked cotangent
    for o_ref, g_ref, dout_ref, dg_ref in (
            (o1_ref, g1_ref, do1_ref, dg1_ref),
            (o2_ref, g2_ref, do2_ref, dg2_ref),
            (o3_ref, g3_ref, do3_ref, dg3_ref)):
        dout_ref[...] = (g_ref[...] * do).astype(dout_ref.dtype)
        dg_ref[...] = jnp.sum(do * o_ref[...].astype(jnp.float32),
                              axis=-1, keepdims=True)


def _specs(t: int, D: int):
    row = pl.BlockSpec((t, D), lambda i: (i, 0))
    col = pl.BlockSpec((t, 1), lambda i: (i, 0))
    return row, col


def _fwd_call(o1, o2, o3, g1, g2, g3, m, *, tile, interpret):
    R, D = o1.shape
    row, col = _specs(tile, D)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(R // tile,),
        in_specs=[row, row, row, col, col, col, col],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((R, D), o1.dtype),
        interpret=interpret,
    )(o1, o2, o3, g1, g2, g3, m)


def _bwd_call(o1, o2, o3, g1, g2, g3, m, do, *, tile, interpret):
    R, D = o1.shape
    row, col = _specs(tile, D)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(R // tile,),
        in_specs=[row, row, row, col, col, col, col, row],
        out_specs=(row, row, row, col, col, col),
        out_shape=(jax.ShapeDtypeStruct((R, D), o1.dtype),
                   jax.ShapeDtypeStruct((R, D), o2.dtype),
                   jax.ShapeDtypeStruct((R, D), o3.dtype),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)),
        interpret=interpret,
    )(o1, o2, o3, g1, g2, g3, m, do)


@functools.lru_cache(maxsize=None)
def _make_vjp(tile: int, interpret: bool):
    kw = dict(tile=tile, interpret=interpret)

    @jax.custom_vjp
    def combine(o1, o2, o3, g1, g2, g3, m):
        return _fwd_call(o1, o2, o3, g1, g2, g3, m, **kw)

    def combine_fwd(o1, o2, o3, g1, g2, g3, m):
        out = _fwd_call(o1, o2, o3, g1, g2, g3, m, **kw)
        return out, (o1, o2, o3, g1, g2, g3, m)

    def combine_bwd(res, do):
        o1, o2, o3, g1, g2, g3, m = res
        do1, do2, do3, dg1, dg2, dg3 = _bwd_call(o1, o2, o3, g1, g2, g3, m, do,
                                                 **kw)
        return do1, do2, do3, dg1, dg2, dg3, None          # mask: no grad

    combine.defvjp(combine_fwd, combine_bwd)
    return combine


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def gated_combine_kernel_call(o1, o2, o3, g1, g2, g3, m, *, tile: int,
                              interpret: bool | None = None):
    """Row-flattened fused epilogue.

    o1..o3: (R, D) branch outputs (any floating dtype);
    g1..g3: (R, 1) fp32 per-row gate values;
    m:      (R, 1) fp32 query-validity (1.0 real / 0.0 padded);
    ``tile`` must divide R (the wrapper pads R up to a multiple).
    Returns (R, D) in o1's dtype.  Differentiable in o1..o3 and g1..g3.
    """
    assert o1.shape[0] % tile == 0, \
        f"rows {o1.shape[0]} not a multiple of tile {tile} (wrapper must pad)"
    if interpret is None:
        interpret = should_interpret()
    return _make_vjp(tile, interpret)(o1, o2, o3, g1, g2, g3, m)
