"""Paged-KV-cache row gather: scalar-prefetched pool-row DMA copies.

``paged_gather_kernel_call(pool (R, H, D), rows (M,) int32) → (M, H, D)``
pulls M arbitrary pool rows (block-table-resolved token or φ-block rows,
``core.nsa_causal.nsa_causal_decode_paged``).  One grid cell per row: the
row index is SCALAR-PREFETCHED, so each cell's input ``index_map`` points
its DMA straight at the pool row and Mosaic pipelines the copies across the
grid — the same ``PrefetchScalarGridSpec`` idiom the varlen kernels use for
per-tile segment ranges.  The kernel body is pure data movement; its point
is that the decode hot path's gathers stream through VMEM as overlapped
row DMAs instead of one monolithic XLA gather materialisation.
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_gather_kernel_call"]


def _copy_kernel(rows_ref, pool_ref, out_ref):
    del rows_ref                       # consumed by the index_map
    out_ref[...] = pool_ref[...]


def paged_gather_kernel_call(pool, rows, *, interpret: bool):
    M = rows.shape[0]
    R, H, D = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, H, D), lambda i, rr: (rr[i], 0, 0))],
        out_specs=pl.BlockSpec((1, H, D), lambda i, rr: (i, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, H, D), pool.dtype),
        interpret=interpret,
    )(rows, pool)
