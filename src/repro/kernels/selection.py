"""Group-selected sparse attention Pallas kernel (the NSA/BSA hot path).

This is the TPU-native realization of the kernel the paper leaves as future
work ("we do not implement a Triton kernel for efficient selection").  The
per-group top-k block indices are **scalar-prefetched** to SMEM
(``pltpu.PrefetchScalarGridSpec``) and drive the K/V BlockSpec index maps, so
each grid step DMAs exactly one selected ℓ-sized KV block HBM→VMEM — a
contiguous burst, the TPU analogue of the paper's "KV blocks fetched in
contiguous chunks" cache-utilisation argument (§2.2 Group selection).

Grid: (B, Hkv, G, k*) with the selected-block index j innermost; flash-style
running-softmax scratch carries the accumulation across the k* blocks of a
group.  The M (rows) dimension of every matmul is the whole query group
(g positions × rep GQA heads), which is what keeps the MXU fed despite tiny
ℓ=8 blocks — exactly the hardware-alignment rationale of NSA group fetch.

Invalid selections are encoded as index −1: the index map clamps them to 0
(a harmless fetch) and the kernel skips their matmuls via ``pl.when`` in
BOTH directions — the backward's dead branch writes its dK/dV staging tiles
as exact zeros.  The selection front-ends additionally invalidate every
selection of an all-padding query group (``occupancy.invalidate_dead_groups``),
so a ragged batch's dead groups skip their whole k* sweep.

PRECISION CONTRACT (``common.resolve_compute_dtype``): operand tiles cast
to the compute dtype (bf16 in → bf16 through QK^T and PV, fp8 QK^T under
REPRO_FP8=1) while every ``dot_general`` accumulates fp32 and the softmax
statistics stay fp32.

Differentiable: the forward emits per-row logsumexp; the backward kernel
runs on the same scalar-prefetched grid, recomputes p = exp(s − lse) per
selected block, accumulates dQ across a group's k* blocks in scratch, and
writes per-selection dK/dV tiles to a (B, Hkv, G, k*, ℓ, D) staging buffer
that the VJP wrapper scatter-adds back through the gathered block indices
(duplicate selections of one block across groups sum correctly there).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, interpret_batch_map, lse_finalize,
                                  mma_dtype, p_from_lse, resolve_compute_dtype,
                                  should_interpret)

__all__ = ["selection_attention_kernel_call"]


def _fwd_kernel(idx_ref,                 # scalar prefetch (B, Hkv, G, k*) int32
                q_ref, k_ref, v_ref, tokbias_ref,
                o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                scale: float, k_star: int, compute: str):
    b = pl.program_id(0)
    h = pl.program_id(1)
    g = pl.program_id(2)
    j = pl.program_id(3)
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = idx_ref[b, h, g, j] >= 0

    @pl.when(valid)
    def _accumulate():
        q = q_ref[0, 0, 0].astype(sdt)                     # (M, D)
        k = k_ref[0, 0, 0].astype(sdt)                     # (ℓ, D)
        v = v_ref[0, 0, 0].astype(adt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + tokbias_ref[0]                             # (ℓ,) padding bias
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        m_scr[...] = m_new
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(adt), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == k_star - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        out = acc_scr[...] / denom
        out = jnp.where(l_scr[...] > 0.0, out, 0.0)        # all-invalid group → 0
        o_ref[0, 0, 0] = out.astype(o_ref.dtype)
        m_safe = jnp.maximum(m_scr[...], NEG_INF / 2)
        lse_ref[0, 0, 0] = lse_finalize(m_safe, l_scr[...])[:, 0]


def _bwd_kernel(idx_ref,
                q_ref, k_ref, v_ref, tokbias_ref, do_ref, lse_ref, delta_ref,
                dq_ref, dkb_ref, dvb_ref, dq_scr, *,
                scale: float, k_star: int, compute: str):
    b = pl.program_id(0)
    h = pl.program_id(1)
    g = pl.program_id(2)
    j = pl.program_id(3)
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    # Invalid selections fetched a clamped (harmless) block; their grid cell
    # skips all five matmuls and writes its dkb/dvb staging tiles as exact
    # zeros — p ≡ 0 there in the oracle, so the skip is bit-exact.
    valid = idx_ref[b, h, g, j] >= 0

    @pl.when(valid)
    def _live_sel():
        q = q_ref[0, 0, 0].astype(sdt)                     # (M, D)
        k = k_ref[0, 0, 0].astype(sdt)                     # (ℓ, D)
        v = v_ref[0, 0, 0].astype(adt)
        do = do_ref[0, 0, 0].astype(adt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + tokbias_ref[0]
        p = p_from_lse(s, lse_ref[0, 0, 0][:, None])       # (M, ℓ)
        dvb_ref[0, 0, 0, 0] = jax.lax.dot_general(
            p.astype(adt), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dvb_ref.dtype)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0, 0][:, None]) * scale
        dkb_ref[0, 0, 0, 0] = jax.lax.dot_general(
            ds.astype(adt), q_ref[0, 0, 0].astype(adt),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dkb_ref.dtype)
        dq_scr[...] += jax.lax.dot_general(ds.astype(adt), k.astype(adt),
                                           (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(valid))
    def _dead_sel():
        dvb_ref[0, 0, 0, 0] = jnp.zeros_like(dvb_ref[0, 0, 0, 0])
        dkb_ref[0, 0, 0, 0] = jnp.zeros_like(dkb_ref[0, 0, 0, 0])

    @pl.when(j == k_star - 1)
    def _finalize():
        dq_ref[0, 0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _fwd_call(q, kb, vb, idx, tok_bias, *, interpret, compute):
    B, Hkv, G, M, D = q.shape
    ell = kb.shape[3]
    k_star = idx.shape[-1]

    def q_map(b, h, g, j, idx_ref):
        return (b, h, g, 0, 0)

    def kv_map(b, h, g, j, idx_ref):
        return (b, h, jnp.maximum(idx_ref[b, h, g, j], 0), 0, 0)

    def tok_map(b, h, g, j, idx_ref):
        return (b, jnp.maximum(idx_ref[b, h, g, j], 0), 0)

    def lse_map(b, h, g, j, idx_ref):
        return (b, h, g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, G, k_star),
        in_specs=[
            pl.BlockSpec((1, 1, 1, M, D), q_map),
            pl.BlockSpec((1, 1, 1, ell, D), kv_map),
            pl.BlockSpec((1, 1, 1, ell, D), kv_map),
            pl.BlockSpec((1, 1, ell), tok_map),
        ],
        out_specs=(pl.BlockSpec((1, 1, 1, M, D), q_map),
                   pl.BlockSpec((1, 1, 1, M), lse_map)),
        scratch_shapes=[
            pltpu.VMEM((M, 1), jnp.float32),
            pltpu.VMEM((M, 1), jnp.float32),
            pltpu.VMEM((M, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=1.0 / (D ** 0.5), k_star=k_star,
                          compute=compute),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((B, Hkv, G, M, D), q.dtype),
                   jax.ShapeDtypeStruct((B, Hkv, G, M), jnp.float32)),
        interpret=interpret,
    )(idx, q, kb, vb, tok_bias)


def _bwd_call(q, kb, vb, idx, tok_bias, do, lse, delta, *, interpret,
              compute):
    B, Hkv, G, M, D = q.shape
    ell = kb.shape[3]
    k_star = idx.shape[-1]

    def q_map(b, h, g, j, idx_ref):
        return (b, h, g, 0, 0)

    def kv_map(b, h, g, j, idx_ref):
        return (b, h, jnp.maximum(idx_ref[b, h, g, j], 0), 0, 0)

    def tok_map(b, h, g, j, idx_ref):
        return (b, jnp.maximum(idx_ref[b, h, g, j], 0), 0)

    def row_map(b, h, g, j, idx_ref):
        return (b, h, g, 0)

    def sel_map(b, h, g, j, idx_ref):
        return (b, h, g, j, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, G, k_star),
        in_specs=[
            pl.BlockSpec((1, 1, 1, M, D), q_map),
            pl.BlockSpec((1, 1, 1, ell, D), kv_map),
            pl.BlockSpec((1, 1, 1, ell, D), kv_map),
            pl.BlockSpec((1, 1, ell), tok_map),
            pl.BlockSpec((1, 1, 1, M, D), q_map),
            pl.BlockSpec((1, 1, 1, M), row_map),
            pl.BlockSpec((1, 1, 1, M), row_map),
        ],
        out_specs=(pl.BlockSpec((1, 1, 1, M, D), q_map),
                   pl.BlockSpec((1, 1, 1, 1, ell, D), sel_map),
                   pl.BlockSpec((1, 1, 1, 1, ell, D), sel_map)),
        scratch_shapes=[pltpu.VMEM((M, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=1.0 / (D ** 0.5), k_star=k_star,
                          compute=compute),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((B, Hkv, G, M, D), q.dtype),
                   jax.ShapeDtypeStruct((B, Hkv, G, k_star, ell, D), kb.dtype),
                   jax.ShapeDtypeStruct((B, Hkv, G, k_star, ell, D), vb.dtype)),
        interpret=interpret,
    )(idx, q, kb, vb, tok_bias, do, lse, delta)


def _scatter_blocks(d_sel, idx, nb: int):
    """Scatter-add per-selection tiles (B,Hkv,G,k*,ℓ,D) back to (B,Hkv,NB,ℓ,D).

    Duplicate selections of one block (across groups) sum; invalid (−1)
    selections were already zeroed by the backward kernel's validity gate but
    are routed to block 0 with zero contribution anyway.
    """
    B, Hkv, G, k_star, ell, D = d_sel.shape
    flat = d_sel.reshape(B, Hkv, G * k_star, ell, D)
    tgt = jnp.maximum(idx.reshape(B, Hkv, G * k_star), 0)

    def scat(buf, i, d):
        return buf.at[i].add(d)

    zeros = jnp.zeros((B, Hkv, nb, ell, D), d_sel.dtype)
    return jax.vmap(jax.vmap(scat))(zeros, tgt, flat)


@functools.lru_cache(maxsize=None)
def _make_vjp(interpret: bool, compute: str):
    kw = dict(interpret=interpret, compute=compute)

    @jax.custom_vjp
    def attend(q, kb, vb, idx, tok_bias):
        return _fwd_call(q, kb, vb, idx, tok_bias, **kw)[0]

    def attend_fwd(q, kb, vb, idx, tok_bias):
        o, lse = _fwd_call(q, kb, vb, idx, tok_bias, **kw)
        return o, (q, kb, vb, idx, tok_bias, o, lse)

    def attend_bwd(res, do):
        q, kb, vb, idx, tok_bias, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        dq, dkb_sel, dvb_sel = _bwd_call(q, kb, vb, idx, tok_bias, do, lse,
                                         delta, **kw)
        nb = kb.shape[2]
        dkb = _scatter_blocks(dkb_sel, idx, nb)
        dvb = _scatter_blocks(dvb_sel, idx, nb)
        return dq, dkb, dvb, None, None                    # idx/bias: no grad

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


@functools.partial(jax.jit, static_argnames=("interpret", "compute"))
def selection_attention_kernel_call(q, kb, vb, idx, tok_bias, *,
                                    interpret: bool | None = None,
                                    compute: str | None = None):
    """Compute group-selected attention.

    q:        (B, Hkv, G, M, D)   query groups (M = g·rep rows)
    kb, vb:   (B, Hkv, NB, ℓ, D)  blocked keys/values
    idx:      (B, Hkv, G, k*) int32 selected block ids, −1 ⇒ invalid
    tok_bias: (B, NB, ℓ) fp32 additive key-padding bias (0 / NEG_INF)
    returns   (B, Hkv, G, M, D)

    Differentiable in q, kb, vb.
    """
    if interpret is None:
        interpret = should_interpret()
    if compute is None:
        compute = resolve_compute_dtype(q.dtype)
    if interpret and q.shape[0] > 1:
        # CPU fallback: per-sample grids keep the interpreter linear in B
        return interpret_batch_map(_make_vjp(True, compute),
                                   q, kb, vb, idx, tok_bias)
    return _make_vjp(interpret, compute)(q, kb, vb, idx, tok_bias)
