"""Ball-Tree Attention Pallas kernel (block-diagonal fused attention).

The ball IS the tile: with ball size m ≤ 512 and head_dim ≤ 128, one ball's
Q/K/V (m×D) fits in VMEM whole, so the kernel is a single-pass fused
softmax-attention per (batch·head, ball) grid cell — no streaming, no
running-max bookkeeping.  MXU-aligned: the two matmuls are (m,D)×(D,m) and
(m,m)×(m,D) with m a multiple of 8 (sublane) and D ∈ {64, 128} (lane).

VMEM budget per grid step (m=256, D=128, bf16 in / fp32 logits):
  q,k,v: 3·256·128·2 B = 192 KiB;  logits+p: 2·256·256·4 B = 512 KiB;
  out: 128 KiB  →  < 1 MiB of the ~16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEG_INF, should_interpret

__all__ = ["ball_attention_kernel_call"]


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale: float):
    q = q_ref[0].astype(jnp.float32)                      # (m, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[0]                                   # (m, m) + (1, m) key bias
    mx = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(s - mx)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-20)
    p = (p / denom).astype(v.dtype)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ball_size", "n_heads", "interpret"))
def ball_attention_kernel_call(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                               key_bias: jnp.ndarray, *, ball_size: int,
                               n_heads: int, interpret: bool | None = None):
    """q,k,v: (BH, N, D) flattened over batch×heads; key_bias: (B, N) fp32
    additive (0 / NEG_INF).  Returns (BH, N, D)."""
    BH, N, D = q.shape
    m = ball_size
    assert N % m == 0
    nballs = N // m
    H = n_heads
    if interpret is None:
        interpret = should_interpret()

    grid = (BH, nballs)
    blk = pl.BlockSpec((1, m, D), lambda b, i: (b, i, 0))
    bias_blk = pl.BlockSpec((1, m), lambda b, i: (b // H, i))
    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (D ** 0.5)),
        grid=grid,
        in_specs=[blk, blk, blk, bias_blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((BH, N, D), q.dtype),
        interpret=interpret,
    )(q, k, v, key_bias)
