"""Ball-Tree Attention Pallas kernel (block-diagonal fused attention).

The ball IS the tile: with ball size m ≤ 512 and head_dim ≤ 128, one ball's
K/V (m×D) fits in VMEM whole, so the kernel is a single-pass fused
softmax-attention per (batch·KV-head, ball) grid cell — no streaming, no
running-max bookkeeping.

GQA-NATIVE: the grid iterates KV heads, not query heads.  Queries arrive as
(B·Hkv, rep, N, D) — the ``rep = Hq/Hkv`` query heads of one GQA group ride
the same grid cell as their shared K/V tile, collapsed into the matmul row
dimension: the two matmuls are (rep·m, D)×(D, m) and (rep·m, m)×(m, D).
One K/V fetch HBM→VMEM serves the whole group (NSA's shared-KV-fetch
speedup), and the extra query rows FEED the MXU rather than re-fetching.
MXU-aligned: rep·m is a multiple of 8 (sublane) and D ∈ {64, 128} (lane).

VMEM budget per grid step (m=256, rep=4, D=128, bf16 in / fp32 logits):
  q: 256 KiB; k,v: 2·64 KiB; logits+p: 2·1024·256·4 B = 2 MiB;
  out: 256 KiB  →  < 3 MiB of the ~16 MiB VMEM.

TILE-OCCUPANCY SKIPPING (``kernels/occupancy.py``): a per-ball
any-valid-key verdict (B, n_b) int32 rides in as a SCALAR-PREFETCH operand.
An all-padding ball (the tail balls of short samples in a ragged batch)
skips both matmuls via ``pl.when`` and writes the exact dead-row answer
directly — zeros with lse = LSE_EMPTY forward, zero dQ/dK/dV backward —
matching the jnp oracle bit-for-bit.

PRECISION CONTRACT (``common.resolve_compute_dtype``): operand tiles cast
to the compute dtype (bf16 in → bf16 through QK^T and PV, fp8 QK^T under
REPRO_FP8=1) while every ``dot_general`` accumulates fp32 and the softmax
statistics stay fp32.

Differentiable: forward additionally emits the per-row logsumexp
(B·Hkv, rep, N); the backward is a single-pass per-ball kernel (the
ball-is-the-tile layout means dQ, dK, dV of a ball depend only on that ball)
that recomputes p = exp(s − lse) and produces all three gradients in one
grid sweep — dK/dV accumulate over the group's rep query heads inside the
(rep·m)-row matmul itself, so no cross-cell reduction is needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (LSE_EMPTY, NEG_INF, interpret_batch_map,
                                  lse_finalize, mma_dtype, p_from_lse,
                                  resolve_compute_dtype, should_interpret)
from repro.kernels.occupancy import key_tile_live

__all__ = ["ball_attention_kernel_call"]


def _fwd_kernel(live_ref, q_ref, k_ref, v_ref, bias_ref, o_ref, lse_ref, *,
                scale: float, nh: int, compute: str):
    b = pl.program_id(0)
    i = pl.program_id(1)
    rep, m, D = q_ref.shape[1:]
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(live_ref[b // nh, i] != 0)
    def _live_ball():
        q = q_ref[0].astype(sdt).reshape(rep * m, D)      # group rows fused
        k = k_ref[0].astype(sdt)                          # (m, D) one fetch/group
        v = v_ref[0].astype(adt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + bias_ref[0]                               # (rep·m, m) + (1, m)
        mx = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), NEG_INF / 2)
        p = jnp.exp(s - mx)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        l = jnp.sum(p, axis=-1, keepdims=True)
        denom = jnp.maximum(l, 1e-20)
        o = jax.lax.dot_general((p / denom).astype(adt), v,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        o_ref[0] = o.reshape(rep, m, D).astype(o_ref.dtype)
        lse_ref[0] = lse_finalize(mx, l)[:, 0].reshape(rep, m)

    @pl.when(live_ref[b // nh, i] == 0)
    def _dead_ball():                                     # all keys masked:
        o_ref[0] = jnp.zeros_like(o_ref[0])               # exact oracle zeros,
        lse_ref[0] = jnp.full_like(lse_ref[0], LSE_EMPTY)  # p ≡ 0 in backward


def _bwd_kernel(live_ref, q_ref, k_ref, v_ref, bias_ref, do_ref, lse_ref,
                delta_ref, dq_ref, dk_ref, dv_ref, *, scale: float, nh: int,
                compute: str):
    b = pl.program_id(0)
    i = pl.program_id(1)
    rep, m, D = q_ref.shape[1:]
    sdt = jnp.dtype(compute)
    adt = jnp.dtype(mma_dtype(compute))

    @pl.when(live_ref[b // nh, i] != 0)
    def _live_ball():
        q = q_ref[0].astype(sdt).reshape(rep * m, D)
        k = k_ref[0].astype(sdt)
        v = v_ref[0].astype(adt)
        do = do_ref[0].astype(adt).reshape(rep * m, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + bias_ref[0]
        p = p_from_lse(s, lse_ref[0].reshape(rep * m, 1))  # (rep·m, m)
        # dK/dV: one matmul sums over the rep·m group rows — the GQA group's
        # gradient accumulation is the contraction itself
        dv = jax.lax.dot_general(p.astype(adt), do, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0].reshape(rep * m, 1)) * scale
        dq = jax.lax.dot_general(ds.astype(adt), k.astype(adt),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dk = jax.lax.dot_general(ds.astype(adt),
                                 q_ref[0].astype(adt).reshape(rep * m, D),
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dq_ref[0] = dq.reshape(rep, m, D).astype(dq_ref.dtype)
        dk_ref[0] = dk.astype(dk_ref.dtype)
        dv_ref[0] = dv.astype(dv_ref.dtype)

    @pl.when(live_ref[b // nh, i] == 0)
    def _dead_ball():                                     # p ≡ 0 → zero grads
        dq_ref[0] = jnp.zeros_like(dq_ref[0])
        dk_ref[0] = jnp.zeros_like(dk_ref[0])
        dv_ref[0] = jnp.zeros_like(dv_ref[0])


def _fwd_call(q, k, v, key_bias, ball_live, *, ball_size, n_heads, interpret,
              compute):
    BH, rep, N, D = q.shape
    m = ball_size
    assert N % m == 0
    H = n_heads                                           # KV heads
    qblk = pl.BlockSpec((1, rep, m, D), lambda b, i, lv: (b, 0, i, 0))
    kvblk = pl.BlockSpec((1, m, D), lambda b, i, lv: (b, i, 0))
    bias_blk = pl.BlockSpec((1, m), lambda b, i, lv: (b // H, i))
    lse_blk = pl.BlockSpec((1, rep, m), lambda b, i, lv: (b, 0, i))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, N // m),
        in_specs=[qblk, kvblk, kvblk, bias_blk],
        out_specs=(qblk, lse_blk),
    )
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=1.0 / (D ** 0.5), nh=H,
                          compute=compute),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, rep, N), jnp.float32)),
        interpret=interpret,
    )(ball_live, q, k, v, key_bias)


def _bwd_call(q, k, v, key_bias, ball_live, do, lse, delta, *, ball_size,
              n_heads, interpret, compute):
    BH, rep, N, D = q.shape
    m = ball_size
    H = n_heads
    qblk = pl.BlockSpec((1, rep, m, D), lambda b, i, lv: (b, 0, i, 0))
    kvblk = pl.BlockSpec((1, m, D), lambda b, i, lv: (b, i, 0))
    bias_blk = pl.BlockSpec((1, m), lambda b, i, lv: (b // H, i))
    row_blk = pl.BlockSpec((1, rep, m), lambda b, i, lv: (b, 0, i))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, N // m),
        in_specs=[qblk, kvblk, kvblk, bias_blk, qblk, row_blk, row_blk],
        out_specs=(qblk, kvblk, kvblk),
    )
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=1.0 / (D ** 0.5), nh=H,
                          compute=compute),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((BH, rep, N, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, N, D), k.dtype),
                   jax.ShapeDtypeStruct((BH, N, D), v.dtype)),
        interpret=interpret,
    )(ball_live, q, k, v, key_bias, do, lse, delta)


@functools.lru_cache(maxsize=None)
def _make_vjp(ball_size: int, n_heads: int, interpret: bool, compute: str):
    kw = dict(ball_size=ball_size, n_heads=n_heads, interpret=interpret,
              compute=compute)

    @jax.custom_vjp
    def attend(q, k, v, key_bias, ball_live):
        return _fwd_call(q, k, v, key_bias, ball_live, **kw)[0]

    def attend_fwd(q, k, v, key_bias, ball_live):
        o, lse = _fwd_call(q, k, v, key_bias, ball_live, **kw)
        return o, (q, k, v, key_bias, ball_live, o, lse)

    def attend_bwd(res, do):
        q, k, v, key_bias, ball_live, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        dq, dk, dv = _bwd_call(q, k, v, key_bias, ball_live, do, lse, delta,
                               **kw)
        return dq, dk, dv, None, None                     # bias/live: no grad

    attend.defvjp(attend_fwd, attend_bwd)
    return attend


@functools.partial(jax.jit, static_argnames=("ball_size", "n_heads",
                                             "interpret", "compute"))
def ball_attention_kernel_call(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                               key_bias: jnp.ndarray, *, ball_size: int,
                               n_heads: int, interpret: bool | None = None,
                               compute: str | None = None):
    """q: (B·Hkv, rep, N, D) grouped queries; k, v: (B·Hkv, N, D) — ONE K/V
    stream per KV head, shared by its ``rep`` query heads; key_bias: (B, N)
    fp32 additive (0 / NEG_INF); ``n_heads`` is the KV head count Hkv.
    ``compute``: canonical matmul-operand dtype name (None resolves from
    q.dtype).  Per-ball liveness is derived from ``key_bias`` and
    scalar-prefetched: all-padding balls skip both matmuls exactly.
    Returns (B·Hkv, rep, N, D).  Differentiable in q, k, v."""
    if interpret is None:
        interpret = should_interpret()
    if compute is None:
        compute = resolve_compute_dtype(q.dtype)
    ball_live = key_tile_live(key_bias, ball_size).astype(jnp.int32)  # (B, n_b)
    if interpret and q.shape[0] > 1:
        # CPU fallback: per-slice grids keep the interpreter linear in B·Hkv
        bias_bh = jnp.repeat(key_bias, n_heads, axis=0)
        live_bh = jnp.repeat(ball_live, n_heads, axis=0)
        return interpret_batch_map(_make_vjp(ball_size, 1, True, compute),
                                   q, k, v, bias_bh, live_bh)
    return _make_vjp(ball_size, n_heads, interpret, compute)(
        q, k, v, key_bias, ball_live)
