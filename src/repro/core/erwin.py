"""Erwin-style baseline: Ball-Tree Attention with hierarchical coarsening.

A faithful-in-spirit reproduction of the comparison system (Zhdanov et al.
2025) used by the paper's Tables 1–3: local attention inside balls, with a
U-Net-style coarsen → attend-at-scale → refine pattern so that global
information propagates through pooled ball centroids rather than through
sparse global branches (BSA's advantage is exactly that it avoids this
progressive fidelity loss).

We implement it as an attention mechanism with the same signature as BSA so
the benchmark harness can swap mechanisms:  per layer, the attention is BTA
at a layer-dependent coarsening level: features are mean-pooled by 2^level
within the ball order, BTA runs on the pooled sequence, and outputs are
un-pooled (nearest-neighbor upsample) back to full resolution.  Execution
routes through the named attention-backend registry (``core/backend.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backend import resolve_backend

__all__ = ["erwin_attention"]


def erwin_attention(q, k, v, *, ball_size: int, level: int = 0,
                    mask=None, backend=None):
    """BTA at coarsening ``level`` (0 = leaf balls, paper's BTA).

    q: (B,N,Hq,D); k,v: (B,N,Hkv,D) — GQA-native: K/V are passed un-repeated
    (the backend owns the group strategy).  For level>0, q/k/v are
    mean-pooled by s=2^level along the sequence, attended within balls of
    ``ball_size`` (so the receptive field covers s·ball_size leaf tokens),
    and the output is un-pooled s× via a broadcast view (Erwin's
    coarsen/refine with skip handled by caller).
    ``backend`` names an attention backend (or passes a Backend object);
    None resolves via the usual precedence chain (default "auto")."""
    B, N, Hq, D = q.shape
    bk = resolve_backend(backend)
    s = 1 << level
    if s > 1:
        assert N % (s * ball_size) == 0, "sequence must cover coarse balls"
        def pool(t):
            H = t.shape[2]
            return t.reshape(B, N // s, s, H, D).mean(axis=2).astype(t.dtype)
        qp, kp, vp = pool(q), pool(k), pool(v)
        mp = None
        if mask is not None:
            mp = mask.reshape(B, N // s, s).any(-1)
        outp = bk.ball(qp, kp, vp, mp, ball_size=ball_size)
        out = jnp.broadcast_to(outp[:, :, None],
                               (B, N // s, s, Hq, D)).reshape(B, N, Hq, D)
    else:
        out = bk.ball(q, k, v, mask, ball_size=ball_size)
    if mask is not None:
        out = jnp.where(mask[:, :, None, None], out, jnp.zeros((), out.dtype))
    return out
