"""Ball Sparse Attention — the paper's primary contribution."""

from repro.core.config import BSAConfig  # noqa: F401
from repro.core.bsa import bsa_init, bsa_attention, ball_attention_ref  # noqa: F401
from repro.core.nsa_causal import (  # noqa: F401
    nsa_init,
    nsa_causal_attention,
    init_decode_cache,
    nsa_causal_decode,
)
from repro.core.full_attention import full_attention  # noqa: F401
from repro.core.erwin import erwin_attention  # noqa: F401
