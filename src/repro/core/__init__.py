"""Ball Sparse Attention — the paper's primary contribution.

Public API and shape conventions (see docs/architecture.md for the full
map from paper sections to modules):

  * :class:`BSAConfig` — all paper hyperparameters (ball size m, compression
    block ℓ, top-k k*, group size g, gating mode) plus implementation knobs
    (``backend``, ``backend_overrides``, ``jnp_chunk_tokens``).
  * Attention backends (``repro.core.backend``): execution is dispatched
    through a named-backend registry — ``"jnp"`` (reference), ``"pallas"``
    (fused TPU kernels), ``"interpret"`` (kernels forced to interpret mode),
    ``"auto"`` (platform pick) — selected by ``BSAConfig.backend``, scoped
    with ``with use_backend("..."):``, forced globally via
    ``REPRO_ATTENTION_BACKEND``, and extended via :func:`register_backend`.
  * :func:`bsa_attention` / :func:`bsa_init` — non-causal BSA on ball-ordered
    point sequences.  q: (B, N, Hq, D); k, v: (B, N, Hkv, D) with
    Hq = Hkv·rep (GQA); ``mask``: (B, N) bool, True = real token — one row
    per sample of a packed ragged batch.  Padded KEYS are invisible (masked
    in logit space everywhere, Pallas kernels included); padded QUERY rows
    are computed but zeroed in the output, so a packed batch of mixed-size
    clouds equals running each cloud alone (tests/test_batching.py).
  * :func:`nsa_causal_attention` / :func:`nsa_init` — the causal 1-D variant
    (LM backend), same shapes and mask semantics; plus
    :func:`init_decode_cache` / :func:`nsa_causal_decode` for incremental
    decoding.
  * :func:`full_attention`, :func:`erwin_attention` — the paper's baselines.
  * :func:`bsa_attention_varlen` — BSA over a PACKED-VARLEN batch: samples
    concatenated on one unbatched (ΣNᵢ, H, D) axis with an ``offsets``
    boundary array instead of dummy-padded batch slots (docs/varlen.md).
    Same semantics as per-sample/bucket-padded, none of the padding FLOPs.
  * Ragged-batching helpers (re-exported from ``repro.core.balltree``):
    ``build_balltree_permutation(s)`` for host-side ball ordering,
    ``pack_ragged`` / ``unpack_ragged`` to move between variable-size clouds
    and one fixed-shape masked batch, ``pack_varlen`` / ``unpack_varlen``
    for the packed-offsets layout, ``bucket_length`` for the geometric
    padding buckets, and ``ragged_ball_order`` for the whole
    order-pack-in-one-call convenience.
"""

from repro.core.config import BSAConfig  # noqa: F401
from repro.core.backend import (  # noqa: F401
    Backend,
    JnpBackend,
    PallasBackend,
    get_backend,
    list_backends,
    register_backend,
    resolve_backend,
    use_backend,
)
from repro.core.bsa import (  # noqa: F401
    bsa_init,
    bsa_attention,
    bsa_attention_varlen,
    ball_attention_ref,
)
from repro.core.nsa_causal import (  # noqa: F401
    nsa_init,
    nsa_causal_attention,
    init_decode_cache,
    nsa_causal_decode,
    init_paged_decode_cache,
    nsa_causal_decode_paged,
)
from repro.core.full_attention import full_attention  # noqa: F401
from repro.core.erwin import erwin_attention  # noqa: F401
from repro.core.balltree import (  # noqa: F401
    ball_order,
    bucket_length,
    build_balltree_permutation,
    build_balltree_permutations,
    pack_ragged,
    pack_varlen,
    pad_to_multiple,
    ragged_ball_order,
    unpack_ragged,
    unpack_varlen,
)
