"""Ball Sparse Attention (BSA) — the paper's contribution, non-causal form.

Operates on ball-ordered point sequences (see ``core/balltree.py``): after the
ball-tree permutation, every contiguous chunk of ``ball_size`` tokens is a
spatially compact ball.  Three branches (paper Eq. 9):

  * ``ball`` — Ball-Tree Attention: full attention inside each ball,
  * ``cmp``  — compression: queries attend to φ-pooled coarse KV blocks,
  * ``slc``  — selection: per query *group*, top-k coarse blocks are fetched
               at token resolution and attended exactly,

combined with sigmoid gates.  Group selection (Eq. 10–12), query-coarsened
scoring (Eq. 13–14), group compression (Eq. 15) and own-ball masking (§3.2)
are all implemented and switchable via :class:`repro.core.config.BSAConfig`.

All functions are shape-polymorphic over GQA: q has ``Hq = Hkv * rep`` heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import (
    accepts_kwarg,
    get_combine,
    get_varlen,
    resolve_branch_backends,
)
from repro.core.branches import (
    NEG_INF,
    block_validity,
    diag_scores,
    gate_values,
    gates_init,
    mask_to_bias,
    phi_apply,
    phi_init,
    score_dtype_cast,
    sdpa,
)
from repro.core.config import BSAConfig
from repro.distributed.sharding import constrain
from repro.numerics import segment_ids_from_offsets

__all__ = ["bsa_init", "bsa_attention", "bsa_attention_varlen",
           "ball_attention_ref"]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def bsa_init(key, cfg: BSAConfig, *, n_heads: int, n_kv_heads: int, head_dim: int,
             d_model: int, param_dtype=jnp.float32) -> dict:
    kk, kv, kq, kg = jax.random.split(key, 4)
    params = {
        "phi_k": phi_init(kk, cfg, head_dim, param_dtype=param_dtype),
        "phi_v": phi_init(kv, cfg, head_dim, param_dtype=param_dtype),
        "gates": gates_init(kg, cfg, n_heads, d_model, param_dtype=param_dtype),
    }
    if cfg.query_cmp_selection or cfg.group_compression:
        params["phi_q"] = phi_init(kq, cfg, head_dim, param_dtype=param_dtype)
    return params


# ---------------------------------------------------------------------------
# Branch 1 — Ball-Tree Attention (block-diagonal)
# ---------------------------------------------------------------------------

def ball_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       mask: jnp.ndarray | None, ball_size: int,
                       chunk_balls: int = 0) -> jnp.ndarray:
    """Full attention within each contiguous ball.  Pure-jnp reference.
    ``chunk_balls`` > 0 processes balls in lax.map tiles (memory bound)."""
    B, N, H, D = q.shape
    m = ball_size
    assert N % m == 0, f"N={N} not a multiple of ball_size={m}"
    nb = N // m
    qb = q.reshape(B, nb, m, H, D).transpose(0, 1, 3, 2, 4)      # (B,nb,H,m,D)
    kb = k.reshape(B, nb, m, H, D).transpose(0, 1, 3, 2, 4)
    vb = v.reshape(B, nb, m, H, D).transpose(0, 1, 3, 2, 4)
    mb = mask.reshape(B, nb, 1, 1, m) if mask is not None else None

    def attend(qc, kc, vc, mc):
        return sdpa(qc, kc, vc, mask_to_bias(mc) if mc is not None else None)

    if chunk_balls and nb % chunk_balls == 0 and nb > chunk_balls:
        nc = nb // chunk_balls
        resh = lambda t: t.reshape(B, nc, chunk_balls, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))
        if mb is not None:
            out = jax.lax.map(jax.checkpoint(lambda t: attend(t[0], t[1], t[2], t[3])),
                              (resh(qb), resh(kb), resh(vb), resh(mb)))
        else:
            out = jax.lax.map(jax.checkpoint(lambda t: attend(t[0], t[1], t[2], None)),
                              (resh(qb), resh(kb), resh(vb)))
        out = out.transpose(1, 0, *range(2, out.ndim)).reshape(B, nb, H, m, D)
    else:
        out = attend(qb, kb, vb, mb)                              # (B,nb,H,m,D)
    return out.transpose(0, 1, 3, 2, 4).reshape(B, N, H, D)


def _ball_branch(q, k, v, mask, cfg: BSAConfig, backend):
    # GQA-native: K/V go in un-repeated — the backend owns the group
    # strategy (kernels share one fetch per group, jnp repeats internally)
    return backend.ball(q, k, v, mask, ball_size=cfg.ball_size,
                        chunk_tokens=cfg.jnp_chunk_tokens)


# ---------------------------------------------------------------------------
# Branch 2 — Compression
# ---------------------------------------------------------------------------

def _compression_branch(params, q, k, v, mask, cfg: BSAConfig, backend):
    """Returns (out, k_cmp, v_cmp, blk_valid). out: (B, N, Hq, D)."""
    B, N, Hq, D = q.shape
    k_cmp = phi_apply(params["phi_k"], k, mask, cfg)              # (B,NB,Hkv,D)
    v_cmp = phi_apply(params["phi_v"], v, mask, cfg)
    blk_valid = block_validity(mask, B, N, cfg.cmp_block)          # (B,NB)
    # GQA-native: the coarse K/V stay at Hkv heads — no repeat_kv blowup

    # q_valid is an OPTIMIZATION HINT: rows it marks invalid are masked by
    # the combine epilogue anyway, so kernels may skip whole dead query
    # tiles.  Probed by signature so third-party backends keep working.
    hint = mask is not None and accepts_kwarg(backend.flash, "q_valid")

    if cfg.group_compression:
        # Eq. 15: pool queries too; attend at block level; un-pool ℓ× via a
        # broadcast VIEW (jnp.repeat would materialise the ℓ-fold copy)
        nb = N // cfg.cmp_block
        q_cmp = phi_apply(params["phi_q"], q, mask, cfg)           # (B,NB,Hq,D)
        kw = {"q_valid": blk_valid} if hint else {}
        out_c = backend.flash(q_cmp, k_cmp, v_cmp, key_valid=blk_valid,
                              chunk_tokens=cfg.jnp_chunk_tokens,
                              **kw)                                # (B,NB,Hq,D)
        out = jnp.broadcast_to(out_c[:, :, None],
                               (B, nb, cfg.cmp_block, Hq, D)
                               ).reshape(B, N, Hq, D)
        return out, k_cmp, v_cmp, blk_valid

    kw = {"q_valid": mask} if hint else {}
    out = backend.flash(q, k_cmp, v_cmp, key_valid=blk_valid,
                        chunk_tokens=cfg.jnp_chunk_tokens, **kw)
    return out, k_cmp, v_cmp, blk_valid


# ---------------------------------------------------------------------------
# Branch 3 — Selection
# ---------------------------------------------------------------------------

def _selection_scores(params, q, k_cmp, blk_valid, mask, cfg: BSAConfig,
                      q_seg=None):
    """Group-level importance scores.

    Returns (scores, n_groups, rows_are_blocks):
      scores: (B, G, Hkv, NB) fp32, already masked (invalid block / own ball).

    ``q_seg``: (N,) int32 per-token segment ids for a packed-varlen axis
    (shared across the batch dim, which is 1 there) — candidate blocks of
    OTHER segments are scored NEG_INF, so top-k never selects across a
    sample boundary and ``sel_valid`` goes False for any that slip in.
    """
    B, N, Hq, D = q.shape
    Hkv = k_cmp.shape[2]
    rep = Hq // Hkv
    nb = k_cmp.shape[1]
    ell = cfg.cmp_block
    g = cfg.group_size if cfg.group_size else 1

    if cfg.query_cmp_selection and cfg.group_size:
        # Eq. 13–14: score with φ-pooled queries (block granularity);
        # q-heads within each GQA group are summed (NSA: shared fetch per group)
        q_s = phi_apply(params["phi_q"], q, mask, cfg)             # (B,NB,Hq,D)
        s = diag_scores(q_s, k_cmp, rep, cfg.score_dtype)           # (B,NB,Hkv,NB)
        rows_per_group = max(g // ell, 1)
        G = nb // rows_per_group
        s = s.reshape(B, G, rows_per_group, Hkv, nb).mean(axis=2)   # Eq. 12 mean
    else:
        # token-level scores; optional group averaging (Eq. 10–12)
        s = diag_scores(q, k_cmp, rep, cfg.score_dtype)             # (B,N,Hkv,NB)
        if cfg.group_size:
            G = N // g
            s = s.reshape(B, G, g, k_cmp.shape[2], nb).mean(axis=2)
        else:
            G = N
    s = s / (D ** 0.5)

    # mask invalid blocks
    s = jnp.where(blk_valid[:, None, None, :], s, NEG_INF)
    if cfg.mask_own_ball:
        tokens_per_group = N // s.shape[1]
        grp_ball = (jnp.arange(s.shape[1]) * tokens_per_group) // cfg.ball_size
        blk_ball = (jnp.arange(nb) * ell) // cfg.ball_size
        own = grp_ball[:, None] == blk_ball[None, :]                # (G,NB)
        s = jnp.where(own[None, :, None, :], NEG_INF, s)
    if q_seg is not None:
        # packed-varlen: a group may only select blocks of its own segment.
        # Offsets are ball_size multiples and groups/blocks subdivide balls,
        # so each group/block is wholly inside one segment — [:, 0] suffices.
        grp_seg = q_seg.reshape(s.shape[1], N // s.shape[1])[:, 0]  # (G,)
        blk_seg = q_seg.reshape(nb, ell)[:, 0]                      # (NB,)
        same = grp_seg[:, None] == blk_seg[None, :]
        s = jnp.where(same[None, :, None, :], s, NEG_INF)
    return s


def _selection_branch(params, q, k, v, k_cmp, blk_valid, mask, cfg: BSAConfig,
                      backend):
    """Top-k block gather + exact attention.  Returns (out, indices)."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block
    nb = N // ell

    scores = _selection_scores(params, q, k_cmp, blk_valid, mask, cfg)  # (B,G,Hkv,NB)
    G = scores.shape[1]
    g = N // G
    k_star = min(cfg.top_k, nb)
    top_vals, top_idx = jax.lax.top_k(scores, k_star)              # (B,G,Hkv,k*)
    sel_valid = top_vals > NEG_INF / 2                              # (B,G,Hkv,k*)

    out = backend.selection(q, k, v, top_idx, sel_valid, mask,
                            block_size=ell, group_size=g,
                            chunk_tokens=cfg.jnp_chunk_tokens)
    return out, top_idx


# ---------------------------------------------------------------------------
# Full BSA
# ---------------------------------------------------------------------------

def bsa_attention(params: dict, q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, cfg: BSAConfig, mask: jnp.ndarray | None = None,
                  x: jnp.ndarray | None = None, return_aux: bool = False):
    """Ball Sparse Attention (paper Eq. 9).

    q: (B, N, Hq, D); k, v: (B, N, Hkv, D); mask: (B, N) bool (True = real).
    Each batch row is an independent (ball-ordered) sample; with per-row
    masks a packed batch of MIXED-SIZE clouds (``core.balltree.pack_ragged``)
    equals running every cloud alone — padded keys are masked in logit space
    on every branch (kernels included), padded query rows are zeroed here.
    ``x`` is the pre-projection layer input, needed only for token gating.
    Returns (B, N, Hq, D) [+ aux dict].
    """
    B, N, Hq, D = q.shape
    assert k.shape[:2] == (B, N) and v.shape == k.shape
    assert Hq % k.shape[2] == 0, "q heads must be a multiple of kv heads"

    # precision contract: under score_dtype="bfloat16" the branch inputs go
    # in bf16 (kernels keep QK^T/PV operands bf16, accumulate fp32) and the
    # combined output is cast back to the caller's dtype at the end.
    in_dtype = q.dtype
    q, k, v = score_dtype_cast(cfg, q, k, v)

    # logical-axis hints for the sharded backend / GSPMD: no-ops outside an
    # axis_rules context (mesh_context enters one), so single-device runs
    # are untouched; under a mesh the glue between shard_mapped ops keeps
    # the sequence dim on the mesh axis instead of bouncing to replicated
    q = constrain(q, "batch", "seq_sp", None, None)
    k = constrain(k, "batch", "seq_sp", None, None)
    v = constrain(v, "batch", "seq_sp", None, None)

    bk = resolve_branch_backends(cfg)
    out_ball = _ball_branch(q, k, v, mask, cfg, bk["ball"])
    out_cmp, k_cmp, v_cmp, blk_valid = _compression_branch(
        params, q, k, v, mask, cfg, bk["cmp"])
    out_slc, top_idx = _selection_branch(
        params, q, k, v, k_cmp, blk_valid, mask, cfg, bk["slc"])

    gates = gate_values(params["gates"], cfg, x, Hq)
    # fused epilogue: gate + sum + query-mask in one pass (the pallas
    # backends run kernels/epilogue.py; others fall back to the jnp ref)
    out = get_combine(bk["ball"])(
        (out_ball, out_cmp, out_slc),
        (gates["ball"], gates["cmp"], gates["slc"]), mask).astype(in_dtype)
    out = constrain(out, "batch", "seq_sp", None, None)
    if return_aux:
        return out, {"ball": out_ball, "cmp": out_cmp, "slc": out_slc,
                     "indices": top_idx, "gates": gates}
    return out


def bsa_attention_varlen(params: dict, q: jnp.ndarray, k: jnp.ndarray,
                         v: jnp.ndarray, *, cfg: BSAConfig,
                         offsets: jnp.ndarray,
                         mask: jnp.ndarray | None = None,
                         x: jnp.ndarray | None = None,
                         return_aux: bool = False):
    """Ball Sparse Attention over a PACKED-VARLEN batch (``docs/varlen.md``).

    q: (T, Hq, D); k, v: (T, Hkv, D) — all samples concatenated on one
    unbatched token axis of capacity T.  ``offsets``: (S+1,) int32 sample
    boundaries, each a multiple of ``cfg.ball_size`` (what
    ``core.balltree.pack_varlen`` emits); trailing repeats are empty slots
    that keep the shape static under jit.  ``mask``: (T,) bool with True on
    real tokens — pass the one from ``pack_varlen`` so per-sample padding
    and the capacity tail beyond ``offsets[-1]`` are masked (without it the
    tail rows compute garbage; real rows are isolated regardless).

    Semantically identical to running each sample alone (or bucket-padded
    via :func:`bsa_attention`): every branch isolates samples — ball and
    selection structurally (offsets are ball multiples, and a group only
    selects blocks of its own segment), compression and local windows via
    in-kernel segment-id masking — but no padding FLOPs are spent on dummy
    batch slots.  ``x`` is the pre-projection input for token gating, shape
    (T, d_model).  Returns (T, Hq, D) [+ aux dict].
    """
    T, Hq, D = q.shape
    assert k.shape[0] == T and v.shape == k.shape
    assert Hq % k.shape[1] == 0, "q heads must be a multiple of kv heads"
    # precision contract — see bsa_attention
    in_dtype = q.dtype
    q, k, v = score_dtype_cast(cfg, q, k, v)
    ell = cfg.cmp_block
    nb = T // ell
    ct = cfg.jnp_chunk_tokens
    maskb = None if mask is None else mask[None]

    bk = resolve_branch_backends(cfg)
    seg = segment_ids_from_offsets(offsets, T)

    # ball branch — block-diagonal by construction (offsets ∈ ball multiples)
    out_ball = get_varlen(bk["ball"], "ball")(
        q, k, v, offsets, mask, ball_size=cfg.ball_size, chunk_tokens=ct)

    # compression branch — packed tokens vs packed φ-blocks; block offsets
    # are exact because sample boundaries are ball (hence ℓ) multiples
    k_cmp = phi_apply(params["phi_k"], k[None], maskb, cfg)[0]     # (NB,Hkv,D)
    v_cmp = phi_apply(params["phi_v"], v[None], maskb, cfg)[0]
    blk_valid = block_validity(maskb, 1, T, ell)                   # (1,NB)
    k_off = offsets // ell
    flash_vl = get_varlen(bk["cmp"], "flash")
    if cfg.group_compression:
        q_cmp = phi_apply(params["phi_q"], q[None], maskb, cfg)[0]
        out_c = flash_vl(q_cmp, k_cmp, v_cmp, k_off, k_off,
                         key_valid=blk_valid[0], chunk_tokens=ct)  # (NB,Hq,D)
        out_cmp = jnp.broadcast_to(out_c[:, None],
                                   (nb, ell, Hq, D)).reshape(T, Hq, D)
    else:
        out_cmp = flash_vl(q, k_cmp, v_cmp, offsets, k_off,
                           key_valid=blk_valid[0], chunk_tokens=ct)

    # selection branch — scores get segment isolation on top of the usual
    # validity/own-ball masking, then the gather-attend is layout-agnostic
    scores = _selection_scores(params, q[None], k_cmp[None], blk_valid,
                               maskb, cfg, q_seg=seg)              # (1,G,Hkv,NB)
    G = scores.shape[1]
    k_star = min(cfg.top_k, nb)
    top_vals, top_idx = jax.lax.top_k(scores, k_star)
    sel_valid = top_vals > NEG_INF / 2
    out_slc = get_varlen(bk["slc"], "selection")(
        q, k, v, top_idx[0], sel_valid[0], offsets, mask,
        block_size=ell, group_size=T // G, chunk_tokens=ct)

    gates = gate_values(params["gates"], cfg,
                        None if x is None else x[None], Hq)
    out = get_combine(bk["ball"])(
        (out_ball[None], out_cmp[None], out_slc[None]),
        (gates["ball"], gates["cmp"], gates["slc"]), maskb)[0].astype(in_dtype)
    if return_aux:
        return out, {"ball": out_ball, "cmp": out_cmp, "slc": out_slc,
                     "indices": top_idx[0], "gates": gates}
    return out
