"""Ball-tree construction (Erwin-style) for imposing regularity on point sets.

The tree is built by recursive median bisection along the axis of largest
extent.  The *only* artifact the model consumes is a permutation that sorts
points into ball order: after permutation, every contiguous chunk of
``ball_size`` points is one ball (a spatially compact neighborhood), and the
chunks at coarser powers of two are the higher tree levels.

Tree construction is data preprocessing (host-side, numpy) — exactly as in
Erwin, where the tree is built on CPU and attention runs on contiguous
chunks.  Everything inside ``jit`` then operates on fixed-shape, ball-ordered
sequences.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "build_balltree_permutation",
    "build_balltree_permutations",
    "ball_order",
    "ragged_ball_order",
    "pad_to_multiple",
    "bucket_length",
    "pack_ragged",
    "pack_items",
    "unpack_ragged",
    "pack_varlen",
    "unpack_varlen",
    "ball_ids",
]


def _bisect(points: np.ndarray, idx: np.ndarray, out: list[np.ndarray], leaf_size: int) -> None:
    """Recursively median-split ``idx`` along the longest axis until leaves
    have at most ``leaf_size`` points; append leaf index arrays to ``out``."""
    if idx.shape[0] <= leaf_size:
        out.append(idx)
        return
    pts = points[idx]
    extent = pts.max(axis=0) - pts.min(axis=0)
    axis = int(np.argmax(extent))
    order = np.argsort(pts[:, axis], kind="stable")
    half = idx.shape[0] // 2
    # split into two equal halves (median split); odd remainder goes left
    left = idx[order[: half + (idx.shape[0] % 2)]]
    right = idx[order[half + (idx.shape[0] % 2):]]
    _bisect(points, left, out, leaf_size)
    _bisect(points, right, out, leaf_size)


def build_balltree_permutation(points: np.ndarray, ball_size: int) -> np.ndarray:
    """Return ``perm`` such that ``points[perm]`` is in ball order.

    ``points``: (N, D) float array.  ``ball_size`` must be a power of two for
    the tree levels to nest; N need NOT be a multiple of ball_size — pad the
    *permuted* sequence afterwards (see :func:`pad_to_multiple`).
    """
    points = np.asarray(points)
    if points.ndim != 2:
        raise ValueError(f"points must be (N, D), got {points.shape}")
    n = points.shape[0]
    if ball_size < 1 or (ball_size & (ball_size - 1)) != 0:
        raise ValueError(f"ball_size must be a positive power of two, got {ball_size}")
    idx = np.arange(n, dtype=np.int64)
    leaves: list[np.ndarray] = []
    _bisect(points, idx, leaves, ball_size)
    return np.concatenate(leaves)


def ball_order(points: np.ndarray, features: np.ndarray, ball_size: int):
    """Convenience: permute ``features`` (and points) into ball order.

    Returns (points_perm, features_perm, perm, inv_perm)."""
    perm = build_balltree_permutation(points, ball_size)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    return points[perm], features[perm], perm, inv


def pad_to_multiple(x: np.ndarray, multiple: int, axis: int = 0, value: float = 0.0):
    """Pad ``x`` along ``axis`` to the next multiple; returns (padded, mask).

    mask is (padded_len,) bool — True for real tokens."""
    n = x.shape[axis]
    target = ((n + multiple - 1) // multiple) * multiple
    pad = target - n
    mask = np.zeros((target,), dtype=bool)
    mask[:n] = True
    if pad == 0:
        return x, mask
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value), mask


def ball_ids(seq_len: int, ball_size: int) -> np.ndarray:
    """ball id per position for a ball-ordered sequence of ``seq_len``."""
    return np.arange(seq_len) // ball_size


# ---------------------------------------------------------------------------
# Ragged batching: variable-size point clouds → one packed (B, L, ...) batch
#
# The model (and the Pallas kernels) consume fixed-shape batches with a
# per-sample validity mask (True = real token).  Variable-size geometries are
# handled entirely here on the host: each cloud is ball-ordered with its OWN
# tree, padded to a shared bucket length, and stacked.  Bucket lengths are
# quantised (geometric in ball counts) so the number of distinct jit shapes
# stays logarithmic in the size range.
# ---------------------------------------------------------------------------

def build_balltree_permutations(points_list, ball_size: int) -> list[np.ndarray]:
    """Ball-tree permutation for each cloud in a ragged batch.

    ``points_list``: sequence of (n_i, D) arrays (n_i may differ per sample).
    Returns a list of per-sample permutations; each tree is built
    independently, so balls never straddle two samples."""
    return [build_balltree_permutation(p, ball_size) for p in points_list]


def bucket_length(n: int, multiple: int, *, geometric: bool = True) -> int:
    """Padded length for an ``n``-point cloud.

    Always a multiple of ``multiple`` (the ball size).  With
    ``geometric=True`` (default) the ball COUNT is additionally rounded up to
    a power of two, so clouds of any size map onto O(log range) distinct
    shapes — one jit compilation per bucket instead of per size.
    """
    if n < 1:
        raise ValueError(f"need at least one point, got n={n}")
    balls = -(-n // multiple)
    if geometric:
        balls = 1 << (balls - 1).bit_length()
    return balls * multiple


def pack_ragged(arrays, multiple: int, *, pad_to: int | None = None,
                value: float = 0.0, geometric: bool = False):
    """Stack variable-length arrays into one BUCKET-PADDED batch.

    ``arrays``: sequence of (n_i, ...) numpy arrays sharing trailing dims.
    Each is padded along axis 0 to a common length L — ``pad_to`` if given
    (must already be ≥ every n_i and a multiple of ``multiple``), else
    ``bucket_length(max n_i)``.  Returns ``(batch (B, L, ...), mask (B, L))``
    with mask True on real rows.  The inverse is :func:`unpack_ragged`.

    This is the PADDED-BUCKET layout: every sample occupies a full L-row
    batch slot, so padding rows of small samples burn real FLOPs/memory in
    every kernel (masked, so the *results* are exact — only the work is
    wasted).  For size-diverse batches prefer the packed-varlen layout
    (:func:`pack_varlen` — one concatenated axis + an offsets array, total
    length ∝ Σ nᵢ instead of B · max nᵢ); see ``docs/varlen.md``.
    """
    arrays = [np.asarray(a) for a in arrays]
    if not arrays:
        raise ValueError("pack_ragged needs at least one array")
    lengths = [a.shape[0] for a in arrays]
    if pad_to is None:
        target = bucket_length(max(lengths), multiple, geometric=geometric)
    else:
        if pad_to % multiple or pad_to < max(lengths):
            raise ValueError(f"pad_to={pad_to} must be a multiple of "
                             f"{multiple} and ≥ max sample size {max(lengths)}")
        target = pad_to
    batch = np.full((len(arrays), target) + arrays[0].shape[1:], value,
                    dtype=arrays[0].dtype)
    mask = np.zeros((len(arrays), target), dtype=bool)
    for i, (a, n) in enumerate(zip(arrays, lengths)):
        batch[i, :n] = a
        mask[i, :n] = True
    return batch, mask


def unpack_ragged(batch: np.ndarray, mask: np.ndarray) -> list[np.ndarray]:
    """Inverse of :func:`pack_ragged`: split a padded batch back into the
    per-sample arrays (padding rows dropped).  Assumes each sample's mask is
    prefix-true (real rows first), which is what pack_ragged produces.
    The packed-varlen counterpart is :func:`unpack_varlen`."""
    batch = np.asarray(batch)
    mask = np.asarray(mask)
    return [batch[i, : int(mask[i].sum())] for i in range(batch.shape[0])]


# ---------------------------------------------------------------------------
# Packed-varlen layout: one concatenated axis + offsets (the cu_seqlens idiom)
#
# Instead of a (B, L, ...) batch padded to the largest sample, all samples
# are concatenated along ONE axis of total length T = Σ paddedᵢ, with an
# ``offsets`` array marking per-sample boundaries — the layout NSA-style
# varlen kernels consume.  Work then scales with the actual token count, not
# B · max nᵢ.  Contract (consumed by ``kernels/ops.py`` varlen wrappers and
# ``core.bsa.bsa_attention_varlen``):
#
#   packed  (T, ...)       samples back-to-back; each padded to a multiple
#                          of ``multiple`` (the ball size) so balls / φ
#                          blocks / selection groups never straddle samples
#   offsets (S+1,) int32   sample i owns rows [offsets[i], offsets[i+1]);
#                          every entry is a multiple of ``multiple``;
#                          monotone non-decreasing.  TRAILING REPEATS are
#                          legal and mean empty segments — they keep the
#                          offsets SHAPE static across batches (jit).
#   mask    (T,) bool      True on real rows (per-sample padding and the
#                          capacity tail are False); prefix-true within
#                          each segment
#
# Rows at/after offsets[-1] are capacity padding shared by no sample.
# ---------------------------------------------------------------------------

def pack_varlen(arrays, multiple: int, *, pad_to: int | None = None,
                max_samples: int | None = None, value: float = 0.0,
                geometric: bool = True):
    """Concatenate variable-length arrays into ONE packed axis + offsets.

    ``arrays``: sequence of (n_i, ...) numpy arrays sharing trailing dims.
    Each sample is padded to the next multiple of ``multiple`` and the padded
    samples are laid back-to-back.  Returns
    ``(packed (T, ...), offsets (S+1,) int32, mask (T,))`` per the contract
    above.

    ``pad_to`` freezes the packed capacity T (must be a multiple of
    ``multiple`` and ≥ the packed total); otherwise T is
    ``bucket_length(total)`` — geometric buckets by default, so jit sees
    O(log size-range) distinct packed shapes regardless of the size MIX.
    ``max_samples`` pads ``offsets`` to a static ``(max_samples + 1,)`` by
    repeating the final boundary (empty trailing segments).

    Inverse: :func:`unpack_varlen`.  Bucket-padded counterpart (one batch
    slot per sample): :func:`pack_ragged`.
    """
    arrays = [np.asarray(a) for a in arrays]
    if not arrays:
        raise ValueError("pack_varlen needs at least one array")
    if max_samples is not None and len(arrays) > max_samples:
        raise ValueError(f"{len(arrays)} samples > max_samples={max_samples}")
    lengths = [a.shape[0] for a in arrays]
    padded = [-(-n // multiple) * multiple for n in lengths]
    total = sum(padded)
    if pad_to is None:
        cap = bucket_length(total, multiple, geometric=geometric)
    else:
        if pad_to % multiple or pad_to < total:
            raise ValueError(f"pad_to={pad_to} must be a multiple of "
                             f"{multiple} and ≥ packed total {total}")
        cap = pad_to
    n_seg = max_samples if max_samples is not None else len(arrays)
    offsets = np.zeros((n_seg + 1,), dtype=np.int32)
    offsets[1:len(arrays) + 1] = np.cumsum(padded)
    offsets[len(arrays) + 1:] = total          # trailing repeats: empty segments
    packed = np.full((cap,) + arrays[0].shape[1:], value, dtype=arrays[0].dtype)
    mask = np.zeros((cap,), dtype=bool)
    for a, n, start in zip(arrays, lengths, offsets[:len(arrays)]):
        packed[start:start + n] = a
        mask[start:start + n] = True
    return packed, offsets, mask


def unpack_varlen(packed: np.ndarray, offsets: np.ndarray,
                  mask: np.ndarray | None = None) -> list[np.ndarray]:
    """Inverse of :func:`pack_varlen`: split the packed axis back into
    per-sample arrays.  With ``mask``, per-sample padding rows are dropped
    (masks are prefix-true within each segment); without it, each sample
    comes back at its padded length.  Empty trailing segments (repeated
    offsets) yield empty arrays."""
    packed = np.asarray(packed)
    offsets = np.asarray(offsets)
    outs = []
    for i in range(offsets.shape[0] - 1):
        a, b = int(offsets[i]), int(offsets[i + 1])
        if mask is not None:
            b = a + int(np.asarray(mask[a:b]).sum())
        outs.append(packed[a:b])
    return outs


def pack_items(items: list[dict], pad_to: int | None) -> dict:
    """Stack per-sample dicts of (padded) arrays into one packed batch dict.

    The dataset-side counterpart of :func:`pack_ragged`: each item maps field
    name → (L_i, ...) array (already ball-multiple length, with a ``feats``
    entry and a bool ``mask``).  All fields re-pad to ``pad_to`` (or the
    batch max); padding rows carry mask=False / zero features, which the
    attention mask semantics treat as invisible keys."""
    target = pad_to or max(it["feats"].shape[0] for it in items)
    return {k: pack_ragged([it[k] for it in items], 1, pad_to=target)[0]
            for k in items[0]}


def ragged_ball_order(points_list, features_list, ball_size: int, *,
                      pad_to: int | None = None, geometric: bool = True):
    """Batched convenience: ball-order and pack a ragged geometry batch.

    Returns ``(points (B,L,D), feats (B,L,F), mask (B,L), perms)`` where
    ``perms`` are the per-sample permutations (needed to map predictions on
    the packed layout back to each cloud's original point order)."""
    perms = build_balltree_permutations(points_list, ball_size)
    pts = [np.asarray(p)[perm] for p, perm in zip(points_list, perms)]
    fts = [np.asarray(f)[perm] for f, perm in zip(features_list, perms)]
    pts_b, mask = pack_ragged(pts, ball_size, pad_to=pad_to, geometric=geometric)
    fts_b, _ = pack_ragged(fts, ball_size, pad_to=pad_to, geometric=geometric)
    return pts_b, fts_b, mask, perms
