"""Ball-tree construction (Erwin-style) for imposing regularity on point sets.

The tree is built by recursive median bisection along the axis of largest
extent.  The *only* artifact the model consumes is a permutation that sorts
points into ball order: after permutation, every contiguous chunk of
``ball_size`` points is one ball (a spatially compact neighborhood), and the
chunks at coarser powers of two are the higher tree levels.

Tree construction is data preprocessing (host-side, numpy) — exactly as in
Erwin, where the tree is built on CPU and attention runs on contiguous
chunks.  Everything inside ``jit`` then operates on fixed-shape, ball-ordered
sequences.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "build_balltree_permutation",
    "ball_order",
    "pad_to_multiple",
    "ball_ids",
]


def _bisect(points: np.ndarray, idx: np.ndarray, out: list[np.ndarray], leaf_size: int) -> None:
    """Recursively median-split ``idx`` along the longest axis until leaves
    have at most ``leaf_size`` points; append leaf index arrays to ``out``."""
    if idx.shape[0] <= leaf_size:
        out.append(idx)
        return
    pts = points[idx]
    extent = pts.max(axis=0) - pts.min(axis=0)
    axis = int(np.argmax(extent))
    order = np.argsort(pts[:, axis], kind="stable")
    half = idx.shape[0] // 2
    # split into two equal halves (median split); odd remainder goes left
    left = idx[order[: half + (idx.shape[0] % 2)]]
    right = idx[order[half + (idx.shape[0] % 2):]]
    _bisect(points, left, out, leaf_size)
    _bisect(points, right, out, leaf_size)


def build_balltree_permutation(points: np.ndarray, ball_size: int) -> np.ndarray:
    """Return ``perm`` such that ``points[perm]`` is in ball order.

    ``points``: (N, D) float array.  ``ball_size`` must be a power of two for
    the tree levels to nest; N need NOT be a multiple of ball_size — pad the
    *permuted* sequence afterwards (see :func:`pad_to_multiple`).
    """
    points = np.asarray(points)
    if points.ndim != 2:
        raise ValueError(f"points must be (N, D), got {points.shape}")
    n = points.shape[0]
    if ball_size < 1 or (ball_size & (ball_size - 1)) != 0:
        raise ValueError(f"ball_size must be a positive power of two, got {ball_size}")
    idx = np.arange(n, dtype=np.int64)
    leaves: list[np.ndarray] = []
    _bisect(points, idx, leaves, ball_size)
    return np.concatenate(leaves)


def ball_order(points: np.ndarray, features: np.ndarray, ball_size: int):
    """Convenience: permute ``features`` (and points) into ball order.

    Returns (points_perm, features_perm, perm, inv_perm)."""
    perm = build_balltree_permutation(points, ball_size)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    return points[perm], features[perm], perm, inv


def pad_to_multiple(x: np.ndarray, multiple: int, axis: int = 0, value: float = 0.0):
    """Pad ``x`` along ``axis`` to the next multiple; returns (padded, mask).

    mask is (padded_len,) bool — True for real tokens."""
    n = x.shape[axis]
    target = ((n + multiple - 1) // multiple) * multiple
    pad = target - n
    mask = np.zeros((target,), dtype=bool)
    mask[:n] = True
    if pad == 0:
        return x, mask
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value), mask


def ball_ids(seq_len: int, ball_size: int) -> np.ndarray:
    """ball id per position for a ball-ordered sequence of ``seq_len``."""
    return np.arange(seq_len) // ball_size
