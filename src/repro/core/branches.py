"""Shared branch math for BSA / causal-NSA: φ compression, gating, attention.

Tensor convention throughout ``core``:
  q: (B, N, Hq, D)    k, v: (B, N, Hkv, D)    with Hq = Hkv * rep (GQA).
Softmax logits are always computed in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.nn import dense, dense_init
from repro.numerics import NEG_INF, mask_to_bias  # noqa: F401 — canonical defs
                                                  # (re-exported for callers)


# ---------------------------------------------------------------------------
# φ — block compression (paper Eq. 5 / Eq. 13)
# ---------------------------------------------------------------------------

def phi_init(key, cfg, head_dim: int, *, param_dtype=jnp.float32) -> dict:
    """Parameters for one φ operator (shared across heads & blocks)."""
    p = {"pos": (jax.random.normal(key, (cfg.cmp_block, head_dim), jnp.float32)
                 * 0.02).astype(param_dtype)}
    if cfg.phi == "mlp":
        k1, k2 = jax.random.split(jax.random.fold_in(key, 1))
        d_in = cfg.cmp_block * head_dim
        p["fc1"] = dense_init(k1, d_in, 2 * head_dim, param_dtype=param_dtype, bias=True)
        p["fc2"] = dense_init(k2, 2 * head_dim, head_dim, param_dtype=param_dtype, bias=True)
    return p


def phi_apply(p: dict, x: jnp.ndarray, mask: jnp.ndarray | None, cfg) -> jnp.ndarray:
    """Compress token blocks to coarse tokens.

    x: (B, N, H, D) → (B, NB, H, D) with NB = N // ℓ.  ``mask``: (B, N) bool
    (True = real token) or None.  Padded positions contribute zero; the mean
    is over valid tokens only.
    """
    B, N, H, D = x.shape
    ell = cfg.cmp_block
    assert N % ell == 0, f"N={N} not a multiple of cmp_block={ell}"
    nb = N // ell
    xb = x.reshape(B, nb, ell, H, D)
    xb = xb + p["pos"].astype(x.dtype)[None, None, :, None, :]
    if mask is not None:
        mb = mask.reshape(B, nb, ell)[..., None, None]          # (B, NB, ℓ, 1, 1)
        xb = jnp.where(mb, xb, jnp.zeros((), x.dtype))
        cnt = jnp.maximum(mask.reshape(B, nb, ell).sum(-1), 1)   # (B, NB)
    else:
        cnt = None
    if cfg.phi == "mean":
        if mask is not None:
            out = xb.sum(axis=2) / cnt[..., None, None].astype(jnp.float32)
            return out.astype(x.dtype)
        return xb.mean(axis=2).astype(x.dtype)
    # MLP φ: flatten block, two-layer MLP (gelu), per head
    flat = xb.transpose(0, 1, 3, 2, 4).reshape(B, nb, H, ell * D)
    h = jax.nn.gelu(dense(p["fc1"], flat).astype(jnp.float32)).astype(x.dtype)
    return dense(p["fc2"], h)


def block_validity(mask: jnp.ndarray | None, B: int, N: int, ell: int) -> jnp.ndarray:
    """(B, NB) bool — a coarse block is valid iff it contains ≥1 real token."""
    nb = N // ell
    if mask is None:
        return jnp.ones((B, nb), bool)
    return mask.reshape(B, nb, ell).any(-1)


# ---------------------------------------------------------------------------
# Gating (paper Eq. 9)
# ---------------------------------------------------------------------------

BRANCHES = ("ball", "cmp", "slc")


def gates_init(key, cfg, n_heads: int, d_model: int, *, param_dtype=jnp.float32) -> dict:
    if cfg.gate_mode == "scalar":
        return {b: jnp.zeros((n_heads,), param_dtype) for b in BRANCHES}
    # token mode: one linear d_model -> 3*H, NSA-style input-dependent gates
    return {"proj": dense_init(key, d_model, 3 * n_heads, param_dtype=param_dtype,
                               scale=0.02, bias=True)}


def gate_values(params: dict, cfg, x: jnp.ndarray | None, n_heads: int):
    """Return dict branch -> gate array broadcastable to (B, N, H, 1)."""
    if cfg.gate_mode == "scalar":
        return {b: jax.nn.sigmoid(params[b].astype(jnp.float32))[None, None, :, None]
                for b in BRANCHES}
    assert x is not None, "token gating needs the layer input"
    g = jax.nn.sigmoid(dense(params["proj"], x).astype(jnp.float32))   # (B, N, 3H)
    B, N, _ = g.shape
    g = g.reshape(B, N, 3, n_heads, 1)
    return {b: g[:, :, i] for i, b in enumerate(BRANCHES)}


def gated_combine_ref(outs, gates, mask):
    """Reference gate-and-mask epilogue (paper Eq. 9 combination).

    ``outs``: three (B, N, H, D) branch outputs; ``gates``: three arrays
    broadcastable to (B, N, H, 1) fp32; ``mask``: (B, N) bool (True = real
    query) or None.  fp32 accumulation, result in ``outs[0].dtype``.  The
    Pallas backends fuse this into one pass (``kernels/ops.gated_combine``);
    this jnp form is the semantic oracle.
    """
    out = sum(g * o.astype(jnp.float32) for g, o in zip(gates, outs))
    if mask is not None:
        out = jnp.where(mask[:, :, None, None], out, 0.0)
    return out.astype(outs[0].dtype)


# ---------------------------------------------------------------------------
# Attention primitives (fp32 softmax; GQA via head reshape)
# ---------------------------------------------------------------------------

def repeat_kv(kv: jnp.ndarray, rep: int) -> jnp.ndarray:
    """(B, N, Hkv, D) -> (B, N, Hkv*rep, D)"""
    if rep == 1:
        return kv
    B, N, Hkv, D = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (B, N, Hkv, rep, D)).reshape(
        B, N, Hkv * rep, D)


def score_dtype_cast(cfg, *tensors):
    """Entry of the kernel-level precision contract: under
    ``score_dtype="bfloat16"`` the attention inputs are cast to bf16 once at
    the top of ``bsa_attention`` / ``nsa_causal_attention``, so every kernel
    resolves a bf16 matmul-operand compute dtype — Q/K/V tiles stay bf16
    through QK^T and PV while accumulation and softmax statistics stay fp32
    (``kernels/common.resolve_compute_dtype``).  fp32 mode returns the
    tensors untouched; the caller casts the combined output back."""
    if cfg.score_dtype == "bfloat16":
        return tuple(t.astype(jnp.bfloat16) for t in tensors)
    return tensors


def diag_scores(q, k_cmp, rep: int, score_dtype=jnp.float32):
    """Selection importance scores q·k_cmpᵀ, GQA-group-summed.

    q: (B, M, Hq, D), k_cmp: (B, NB, Hkv, D) -> (B, M, Hkv, NB) fp32,
    summing the ``rep`` q-heads of each GQA group (NSA's shared-importance
    trick).  Operands are cast ONCE to ``score_dtype`` (``BSAConfig.
    score_dtype``) — fp32 by default; bf16 keeps the einsum on bf16 MXU
    paths instead of silently upcasting activations mid-einsum.  The
    contraction always ACCUMULATES in fp32 and the result is fp32 either
    way, so top-k ordering is computed at full precision.
    """
    B, M, Hq, D = q.shape
    Hkv = k_cmp.shape[2]
    assert Hq == Hkv * rep, f"GQA miswiring: Hq={Hq} != Hkv={Hkv} * rep={rep}"
    dt = jnp.dtype(score_dtype)
    qg = q.reshape(B, M, Hkv, rep, D).astype(dt)
    return jnp.einsum("bmkrd,bnkd->bmkn", qg, k_cmp.astype(dt),
                      preferred_element_type=jnp.float32)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """softmax(q kᵀ/√D + bias) v.

    q: (..., M, D), k/v: (..., L, D), bias broadcastable to (..., M, L).
    Rows whose keys are ALL masked (bias = NEG_INF) return zeros.
    """
    d = q.shape[-1]
    logits = jnp.einsum("...md,...ld->...ml", q, k,
                        preferred_element_type=jnp.float32) / (d ** 0.5)
    if bias is not None:
        logits = logits + bias
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)            # guard all-masked rows
    p = jnp.exp(logits - m)
    if bias is not None:
        p = jnp.where(logits <= NEG_INF / 2, 0.0, p)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    out = jnp.einsum("...ml,...ld->...md", (p / denom).astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Memory-bounded (chunked) attention paths for the pure-jnp fallback.
#
# The Pallas kernels stream these computations through VMEM on real TPUs; the
# jnp fallback would otherwise materialise O(N·k*·ℓ) selection logits and
# O(N·N/ℓ) compression logits — at 32k tokens that is tens of GiB.  With
# ``cfg.jnp_chunk_tokens`` set, query tiles are processed under ``lax.map``
# so peak temp memory is bounded by one tile (XLA keeps one body live).
# ---------------------------------------------------------------------------

def gather_attend_blocks(q_g, kb, vb, idx, sel_valid, tok_valid, scale_dim: int):
    """Selection attention for grouped queries.

    q_g: (G, B, g, Hkv, rep, D);  kb/vb: (B, Hkv, NB, ℓ, D) HEAD-MAJOR;
    idx/sel_valid: (G, B, Hkv, k*);  tok_valid: (B, NB, ℓ) bool or None.
    Returns (G, B, g, Hkv, rep, D).

    The block fetch is a BATCHED ``take_along_axis`` with (B, Hkv) as batch
    dims — GSPMD keeps the sharded head axis local.  (The obvious multi-dim
    advanced-indexing gather makes the partitioner replicate the gather and
    all-reduce a full-KV-sized tensor PER CHUNK — §Perf iteration 1 measured
    that at 42 TiB of AR per step on stablelm train_4k.)"""
    G, B, g, Hkv, rep, D = q_g.shape
    NB, ell = kb.shape[2], kb.shape[3]
    k_star = idx.shape[-1]
    L = k_star * ell
    safe_idx = jnp.where(sel_valid, idx, 0)
    ig = safe_idx.transpose(1, 2, 0, 3).reshape(B, Hkv, G * k_star)
    kg = jnp.take_along_axis(kb.reshape(B, Hkv, NB, ell * D),
                             ig[..., None], axis=2).reshape(B, Hkv, G, L, D)
    vg = jnp.take_along_axis(vb.reshape(B, Hkv, NB, ell * D),
                             ig[..., None], axis=2).reshape(B, Hkv, G, L, D)
    key_valid = jnp.broadcast_to(
        sel_valid.transpose(1, 2, 0, 3)[..., None], (B, Hkv, G, k_star, ell))
    if tok_valid is not None:
        tv = jnp.take_along_axis(tok_valid.reshape(B, 1, NB, ell),
                                 ig[..., None], axis=2)
        key_valid = key_valid & tv.reshape(B, Hkv, G, k_star, ell)
    bias = mask_to_bias(key_valid.reshape(B, Hkv, G, 1, 1, L))
    qh = q_g.transpose(1, 3, 0, 4, 2, 5)                 # (B,Hkv,G,rep,g,D)
    logits = jnp.einsum("bhgrmd,bhgld->bhgrml", qh, kg,
                        preferred_element_type=jnp.float32) / (scale_dim ** 0.5)
    logits = logits + bias
    mx = jnp.maximum(logits.max(-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - mx)
    p = jnp.where(logits <= NEG_INF / 2, 0.0, p)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    out = jnp.einsum("bhgrml,bhgld->bhgrmd", p.astype(vg.dtype), vg,
                     preferred_element_type=jnp.float32).astype(vg.dtype)
    return out.transpose(2, 0, 4, 1, 3, 5)               # (G,B,g,Hkv,rep,D)


def selection_attend(q, k, v, top_idx, sel_valid, mask, *, block_size: int,
                     chunk_tokens: int = 0, q_valid=None):
    """Orchestrates layout + optional chunking for the jnp selection branch.

    q: (B,N,Hq,D); k/v: (B,L,Hkv,D); top_idx/sel_valid: (B,G,Hkv,k*);
    ``block_size`` is the KV block length ℓ, ``chunk_tokens`` the optional
    query-memory bound.  Returns (B,N,Hq,D).  L may exceed N (context-
    parallel shards pass a local query slab against the full key set);
    ``mask`` stays KEY-sized (B, L) and ``q_valid`` (B, N), when given,
    supplies query-side validity separately — without it the key mask
    doubles as the query mask (the classic N == L layout).

    Groups whose query tokens are ALL padded get their selections
    invalidated (→ exact zeros), matching the kernel path's dead-group
    skipping — so oracle and kernel agree bit-for-bit on padded rows."""
    from repro.kernels.occupancy import invalidate_dead_groups
    sel_valid = invalidate_dead_groups(
        sel_valid, q_valid if q_valid is not None else mask, q.shape[1])
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = block_size
    nb = k.shape[1] // ell
    G = top_idx.shape[1]
    g = N // G
    kb = k.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)  # head-major
    vb = v.reshape(B, nb, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    tok_valid = mask.reshape(B, nb, ell) if mask is not None else None
    q_g = q.reshape(B, G, g, Hkv, rep, D).transpose(1, 0, 2, 3, 4, 5)
    idx_g = top_idx.transpose(1, 0, 2, 3)
    val_g = sel_valid.transpose(1, 0, 2, 3)

    chunk_groups = max(chunk_tokens // g, 1) if chunk_tokens else 0
    if chunk_groups and G % chunk_groups == 0 and G > chunk_groups:
        nc = G // chunk_groups
        xs = (q_g.reshape(nc, chunk_groups, *q_g.shape[1:]),
              idx_g.reshape(nc, chunk_groups, *idx_g.shape[1:]),
              val_g.reshape(nc, chunk_groups, *val_g.shape[1:]))
        body = jax.checkpoint(  # recompute chunk logits in backward —
            lambda t: gather_attend_blocks(t[0], kb, vb, t[1], t[2], tok_valid, D))
        out = jax.lax.map(body, xs)  # saved residuals stay O(chunk)
        out = out.reshape(G, B, g, Hkv, rep, D)
    else:
        out = gather_attend_blocks(q_g, kb, vb, idx_g, val_g, tok_valid, D)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, N, Hq, D)


def chunked_q_attention(q, k, v, *, key_valid=None, block_causal_ell: int = 0,
                        chunk: int = 0, q_seg=None, k_seg=None, pos0=0):
    """Dense attention of q vs (small) K/V with optional query chunking.

    q: (B,N,H,D); k/v: (B,L,H,D) same head count; key_valid: (B,L) bool.
    block_causal_ell>0 applies the compression-branch causal rule:
    query t attends key j iff (j+1)·ell − 1 < t.
    ``pos0`` offsets ONLY that causal rule: a context-parallel shard whose
    local row 0 sits at global position pos0 passes its shard offset (may be
    a traced scalar, e.g. ``axis_index * n_local``) while ``q_seg`` indexing
    stays local.  pos0 and q_seg are never used together.
    ``q_seg``/``k_seg`` (given together): (N,)/(L,) int32 segment ids shared
    across the batch — packed-varlen isolation, a query only attends keys of
    its own segment (``numerics.segment_ids_from_offsets``)."""
    B, N, H, D = q.shape
    L = k.shape[1]
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    base_bias = mask_to_bias(key_valid[:, None, None, :]) if key_valid is not None \
        else jnp.zeros((B, 1, 1, L), jnp.float32)

    def attend(qc, pos):
        # qc: (B,H,c,D); pos: (c,) absolute positions
        bias = base_bias
        if block_causal_ell:
            end = (jnp.arange(L) + 1) * block_causal_ell - 1
            bias = bias + mask_to_bias(end[None, :] < (pos + pos0)[:, None])[None, None]
        if q_seg is not None:
            bias = bias + mask_to_bias(q_seg[pos][:, None] == k_seg[None, :])[None, None]
        return sdpa(qc, kh, vh, bias)

    qh = q.transpose(0, 2, 1, 3)                                  # (B,H,N,D)
    if chunk and N % chunk == 0 and N > chunk:
        nc = N // chunk
        qcs = qh.reshape(B, H, nc, chunk, D).transpose(2, 0, 1, 3, 4)
        pos = jnp.arange(N).reshape(nc, chunk)
        out = jax.lax.map(jax.checkpoint(lambda t: attend(t[0], t[1])), (qcs, pos))
        out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, N, D)
    else:
        out = attend(qh, jnp.arange(N))
    return out.transpose(0, 2, 1, 3)
