"""Full (dense) attention baseline — the paper's accuracy upper bound."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backend import resolve_backend
from repro.core.branches import repeat_kv

__all__ = ["full_attention"]


def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   mask: jnp.ndarray | None = None, causal: bool = False,
                   backend=None) -> jnp.ndarray:
    """q: (B,N,Hq,D); k,v: (B,L,Hkv,D); mask: (B,L) key validity.

    ``backend`` names an attention backend (or passes a Backend object);
    None resolves via the usual precedence chain (default "auto").
    """
    rep = q.shape[2] // k.shape[2]
    kf, vf = repeat_kv(k, rep), repeat_kv(v, rep)
    bk = resolve_backend(backend)
    return bk.flash(q, kf, vf, key_valid=mask, causal=causal)
