"""Full (dense) attention baseline — the paper's accuracy upper bound."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backend import resolve_backend

__all__ = ["full_attention"]


def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   mask: jnp.ndarray | None = None, causal: bool = False,
                   backend=None) -> jnp.ndarray:
    """q: (B,N,Hq,D); k,v: (B,L,Hkv,D); mask: (B,L) key validity.

    GQA-native: K/V are passed to the backend un-repeated (kernels share one
    K/V fetch per GQA group; the jnp reference repeats internally).
    ``backend`` names an attention backend (or passes a Backend object);
    None resolves via the usual precedence chain (default "auto").
    """
    bk = resolve_backend(backend)
    return bk.flash(q, k, v, key_valid=mask, causal=causal)
