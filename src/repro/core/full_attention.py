"""Full (dense) attention baseline — the paper's accuracy upper bound."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.branches import mask_to_bias, repeat_kv, sdpa

__all__ = ["full_attention"]


def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   mask: jnp.ndarray | None = None, causal: bool = False,
                   use_kernels: bool = False) -> jnp.ndarray:
    """q: (B,N,Hq,D); k,v: (B,L,Hkv,D); mask: (B,L) key validity."""
    B, N, Hq, D = q.shape
    L = k.shape[1]
    rep = Hq // k.shape[2]
    kf, vf = repeat_kv(k, rep), repeat_kv(v, rep)

    if use_kernels:
        from repro.kernels import ops as kops
        assert L == N or not causal, "kernel path assumes aligned q/k for causal"
        return kops.flash_attention(q, kf, vf, key_valid=mask, causal=causal)

    bias = jnp.zeros((1, 1, 1, L), jnp.float32)
    if mask is not None:
        bias = bias + mask_to_bias(mask[:, None, None, :])
    if causal:
        qi = jnp.arange(N)[:, None] + (L - N)      # align ends (cache decoding)
        ki = jnp.arange(L)[None, :]
        bias = bias + mask_to_bias((ki <= qi)[None, None])
    out = sdpa(q.transpose(0, 2, 1, 3), kf.transpose(0, 2, 1, 3),
               vf.transpose(0, 2, 1, 3), bias)
    return out.transpose(0, 2, 1, 3)
