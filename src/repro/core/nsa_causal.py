"""Causal BSA/NSA for 1-D token sequences (the LM-architecture backend).

For 1-D sequences the ball tree degenerates to contiguous blocks, so BSA
reduces to NSA with a *blocked local window* instead of the ball branch:

  * ``ball`` → blocked local attention: each query block (size w) attends
    causally within itself plus fully to the previous block (effective
    receptive window w..2w).  This replaces NSA's per-token sliding window
    with the hardware-aligned blocked equivalent (same trick Longformer /
    block-local FlashAttention use on TPU).
  * ``cmp``  → φ-compressed KV blocks; query t attends to every block that
    ends strictly before t.
  * ``slc``  → top-k *strictly past* blocks per query group (group-causal:
    a block is selectable iff it ends before the group starts, so one
    selection is causally valid for every query in the group).  The current
    block is covered by the local branch (NSA instead force-selects it; we
    document this deviation in DESIGN.md — the local branch already attends
    to it exactly).  ``force_first_block`` keeps NSA's always-select-initial
    -block behaviour.

Both a full-sequence train path and an incremental decode path (KV cache +
compressed-KV cache) are provided.  The decode path is O(w + S/ℓ + k*ℓ)
per token — sub-quadratic end-to-end, which is what makes ``long_500k``
serveable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import (
    accepts_kwarg,
    get_combine,
    get_paged_gather,
    resolve_branch_backends,
)
from repro.core.branches import (
    NEG_INF,
    block_validity,
    diag_scores,
    gate_values,
    gates_init,
    mask_to_bias,
    phi_apply,
    phi_init,
    repeat_kv,
    score_dtype_cast,
    sdpa,
)
from repro.core.config import BSAConfig
from repro.distributed.sharding import constrain

__all__ = [
    "nsa_init",
    "nsa_causal_attention",
    "init_decode_cache",
    "nsa_causal_decode",
    "init_paged_decode_cache",
    "nsa_causal_decode_paged",
    "local_window_attention_ref",
]


def nsa_init(key, cfg: BSAConfig, *, n_heads: int, n_kv_heads: int, head_dim: int,
             d_model: int, param_dtype=jnp.float32) -> dict:
    kk, kv, kq, kg = jax.random.split(key, 4)
    params = {
        "phi_k": phi_init(kk, cfg, head_dim, param_dtype=param_dtype),
        "phi_v": phi_init(kv, cfg, head_dim, param_dtype=param_dtype),
        "gates": gates_init(kg, cfg, n_heads, d_model, param_dtype=param_dtype),
    }
    if cfg.query_cmp_selection:
        params["phi_q"] = phi_init(kq, cfg, head_dim, param_dtype=param_dtype)
    return params


# ---------------------------------------------------------------------------
# Local branch — blocked causal window
# ---------------------------------------------------------------------------

def local_window_attention_ref(q, k, v, window: int, mask=None,
                               chunk_blocks: int = 0, block_seg=None):
    """Blocked local causal attention (pure-jnp reference).

    q,k,v: (B, N, H, D) with equal head counts.  Query block i attends to
    block i (causal) and block i-1 (full).  ``mask``: (B, N) bool key
    validity (True = real token) for packed ragged batches, or None.
    ``chunk_blocks`` > 0 bounds temp memory via lax.map tiles over blocks.
    ``block_seg``: (nb,) int32 per-BLOCK segment ids (packed-varlen layout,
    offsets multiples of ``window``) — the previous-block half is masked off
    whenever block i-1 belongs to a different segment, so windows never leak
    across sample boundaries."""
    B, N, H, D = q.shape
    w = window
    assert N % w == 0, f"N={N} not a multiple of local window {w}"
    nb = N // w
    qb = q.reshape(B, nb, w, H, D).transpose(0, 1, 3, 2, 4)        # (B,nb,H,w,D)
    kb = k.reshape(B, nb, w, H, D).transpose(0, 1, 3, 2, 4)
    vb = v.reshape(B, nb, w, H, D).transpose(0, 1, 3, 2, 4)
    # previous block (block -1 is zeros, fully masked)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kcat = jnp.concatenate([kprev, kb], axis=3)                     # (B,nb,H,2w,D)
    vcat = jnp.concatenate([vprev, vb], axis=3)
    qi = jnp.arange(w)[:, None]
    ki = jnp.arange(2 * w)[None, :]
    causal = ki <= qi + w                                           # allow prev + self-causal
    first = jnp.concatenate([jnp.zeros((w, w), bool), jnp.tril(jnp.ones((w, w), bool))], axis=1)
    bias = mask_to_bias(causal)                                     # (w, 2w)
    bias_first = mask_to_bias(first)
    biases = jnp.where((jnp.arange(nb) == 0)[:, None, None], bias_first[None], bias[None])
    biases = biases[None, :, None]                                  # (1,nb,1,w,2w)
    if block_seg is not None:
        # kill the prev-block half where block i-1 is a different segment
        prev_ok = jnp.concatenate([jnp.zeros((1,), bool),
                                   block_seg[1:] == block_seg[:-1]])
        prev_allow = jnp.concatenate(
            [jnp.broadcast_to(prev_ok[:, None], (nb, w)),
             jnp.ones((nb, w), bool)], axis=1)                      # (nb,2w)
        biases = biases + mask_to_bias(prev_allow)[None, :, None, None, :]
    if mask is not None:
        mb = mask.reshape(B, nb, w)
        mprev = jnp.concatenate([jnp.ones_like(mb[:, :1]), mb[:, :-1]], axis=1)
        mcat = jnp.concatenate([mprev, mb], axis=2)                 # (B,nb,2w)
        biases = biases + mask_to_bias(mcat[:, :, None, None, :])

    if chunk_blocks and nb % chunk_blocks == 0 and nb > chunk_blocks:
        nc = nb // chunk_blocks
        resh = lambda t: t.reshape(t.shape[0], nc, chunk_blocks, *t.shape[2:]) \
                          .transpose(1, 0, *range(2, t.ndim + 1))
        out = jax.lax.map(jax.checkpoint(lambda t: sdpa(t[0], t[1], t[2], t[3])),
                          (resh(qb), resh(kcat), resh(vcat),
                           resh(jnp.broadcast_to(biases, (B,) + biases.shape[1:]))))
        out = out.transpose(1, 0, *range(2, out.ndim)).reshape(B, nb, H, w, D)
    else:
        out = sdpa(qb, kcat, vcat, biases)                          # (B,nb,H,w,D)
    return out.transpose(0, 1, 3, 2, 4).reshape(B, N, H, D)


def _local_branch(q, k, v, mask, cfg: BSAConfig, backend):
    # GQA-native: un-repeated K/V — the backend owns the group strategy
    return backend.local_window(q, k, v, window=cfg.effective_local_window,
                                mask=mask, chunk_tokens=cfg.jnp_chunk_tokens)


# ---------------------------------------------------------------------------
# Train-time causal NSA
# ---------------------------------------------------------------------------

def nsa_causal_attention(params, q, k, v, *, cfg: BSAConfig,
                         mask: jnp.ndarray | None = None,
                         x: jnp.ndarray | None = None,
                         return_aux: bool = False):
    """Causal BSA.  q: (B,N,Hq,D); k,v: (B,N,Hkv,D)."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block

    # precision contract: under score_dtype="bfloat16" the branch inputs go
    # in bf16 (kernels keep QK^T/PV operands bf16, accumulate fp32) and the
    # combined output is cast back to the caller's dtype at the end.
    in_dtype = q.dtype
    q, k, v = score_dtype_cast(cfg, q, k, v)

    # logical-axis hints for the sharded backend (no-op outside a mesh /
    # axis_rules context) — keeps glue between shard_mapped ops seq-sharded
    q = constrain(q, "batch", "seq_sp", None, None)
    k = constrain(k, "batch", "seq_sp", None, None)
    v = constrain(v, "batch", "seq_sp", None, None)

    bk = resolve_branch_backends(cfg)
    out_local = _local_branch(q, k, v, mask, cfg, bk["ball"])

    # --- compression (GQA-native: coarse K/V stay at Hkv heads) ---
    k_cmp = phi_apply(params["phi_k"], k, mask, cfg)                # (B,NB,Hkv,D)
    v_cmp = phi_apply(params["phi_v"], v, mask, cfg)
    blk_valid = block_validity(mask, B, N, ell)
    # block-causal rule (query t sees coarse key j iff block j ends before t)
    # is generated by the backend — in-kernel on pallas, bias on jnp.
    # q_valid is an occupancy HINT (padded query rows are masked by the
    # combine epilogue, so kernels may skip whole dead query tiles);
    # probed by signature so third-party backends keep working.
    kw = ({"q_valid": mask}
          if mask is not None and accepts_kwarg(bk["cmp"].flash, "q_valid")
          else {})
    out_cmp = bk["cmp"].flash(q, k_cmp, v_cmp, key_valid=blk_valid,
                              block_causal=True, ell=ell,
                              chunk_tokens=cfg.jnp_chunk_tokens, **kw)

    # --- selection ---
    out_slc, top_idx = _causal_selection(params, q, k, v, k_cmp, blk_valid,
                                         mask, cfg, bk["slc"])

    gates = gate_values(params["gates"], cfg, x, Hq)
    # fused epilogue: gate + sum + query-mask in one pass (see core/bsa.py)
    out = get_combine(bk["ball"])(
        (out_local, out_cmp, out_slc),
        (gates["ball"], gates["cmp"], gates["slc"]), mask).astype(in_dtype)
    out = constrain(out, "batch", "seq_sp", None, None)
    if return_aux:
        return out, {"local": out_local, "cmp": out_cmp, "slc": out_slc,
                     "indices": top_idx, "gates": gates}
    return out


def _causal_selection(params, q, k, v, k_cmp, blk_valid, mask, cfg: BSAConfig,
                      backend):
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block
    nb = N // ell
    g = cfg.group_size if cfg.group_size else 1

    # scores (shared diag_scores: one cast to cfg.score_dtype, fp32 accumulate)
    if cfg.query_cmp_selection and cfg.group_size:
        q_s = phi_apply(params["phi_q"], q, mask, cfg)              # (B,NB,Hq,D)
        s = diag_scores(q_s, k_cmp, rep, cfg.score_dtype)           # (B,NB,Hkv,NB)
        rows_per_group = max(g // ell, 1)
        G = nb // rows_per_group
        s = s.reshape(B, G, rows_per_group, Hkv, nb).mean(axis=2)
    else:
        s = diag_scores(q, k_cmp, rep, cfg.score_dtype)             # (B,N,Hkv,NB)
        if cfg.group_size:
            G = N // g
            s = s.reshape(B, G, g, Hkv, nb).mean(axis=2)
        else:
            G = N
    s = s / (D ** 0.5)

    tokens_per_group = N // s.shape[1]
    G = s.shape[1]
    grp_start = jnp.arange(G) * tokens_per_group
    blk_end = jnp.arange(nb) * ell + (ell - 1)
    causal = blk_end[None, :] < grp_start[:, None]                  # (G,NB): strictly past
    s = jnp.where(causal[None, :, None, :], s, NEG_INF)
    s = jnp.where(blk_valid[:, None, None, :], s, NEG_INF)
    if cfg.force_first_block:
        # NSA always selects the initial block (when causally valid)
        boost = jnp.where(causal[:, :1], -NEG_INF, 0.0)             # (G,1)
        s = s.at[..., 0].add(boost[None, :, None, 0])

    k_star = min(cfg.top_k, nb)
    top_vals, top_idx = jax.lax.top_k(s, k_star)                    # (B,G,Hkv,k*)
    sel_valid = top_vals > NEG_INF / 2

    # gather & attend (strictly-past blocks ⇒ no intra-block causal mask)
    out = backend.selection(q, k, v, top_idx, sel_valid, mask,
                            block_size=ell, group_size=N // G,
                            chunk_tokens=cfg.jnp_chunk_tokens)
    return out, top_idx


# ---------------------------------------------------------------------------
# Decode path (incremental, cached)
# ---------------------------------------------------------------------------

def init_decode_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                      cfg: BSAConfig, dtype=jnp.bfloat16) -> dict:
    w = cfg.effective_local_window
    if max_len < 2 * w or max_len % w:
        raise ValueError(f"max_len={max_len} must be a multiple of the local "
                         f"window {w} and at least 2×")
    nb = max_len // cfg.cmp_block
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "k_cmp": jnp.zeros((batch, nb, n_kv_heads, head_dim), dtype),
        "v_cmp": jnp.zeros((batch, nb, n_kv_heads, head_dim), dtype),
        "length": jnp.zeros((), jnp.int32),   # tokens already in cache
    }


def init_paged_decode_cache(num_blocks: int, page: int, n_kv_heads: int,
                            head_dim: int, cfg: BSAConfig,
                            dtype=jnp.bfloat16) -> dict:
    """Paged decode cache: flat KV POOLS shared by every slot.

    ``num_blocks`` pool blocks of ``page`` tokens each, PLUS one TRASH block
    (id ``num_blocks``) that absorbs writes from inactive slots and reads
    through unallocated block-table entries — so the jitted step needs no
    data-dependent shapes.  ``page`` must be a multiple of both the local
    window w (the 2w window then never crosses into an unallocated page)
    and the compression block ℓ (a φ-block never straddles pages; block j's
    compressed row lives in the SAME pool block as its tokens, which is what
    lets prefix-cached pages carry their compressed state for free).
    """
    w = cfg.effective_local_window
    ell = cfg.cmp_block
    if page % w or page % ell:
        raise ValueError(f"page={page} must be a multiple of the local window "
                         f"{w} and of cmp_block {ell}")
    R = (num_blocks + 1) * page
    Rc = (num_blocks + 1) * (page // ell)
    return {
        "k": jnp.zeros((R, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((R, n_kv_heads, head_dim), dtype),
        "k_cmp": jnp.zeros((Rc, n_kv_heads, head_dim), dtype),
        "v_cmp": jnp.zeros((Rc, n_kv_heads, head_dim), dtype),
    }


def _cmp_attend_from_rows(kc_all, vc_all, q1, blk_ok, rep):
    """Reference compression-branch attention + selection block scores.

    kc_all/vc_all (B, NB, Hkv, D) gathered compressed rows; q1 (B,1,Hq,D);
    blk_ok (B, NB) bool.  Returns (out_cmp (B,Hq,1,D), scores (B,Hkv,NB)
    fp32 with NEG_INF on dead blocks) — the dense semantics every
    ``cmp_attend`` implementation must match."""
    B, _, Hq, D = q1.shape
    Hkv = kc_all.shape[2]
    qh = q1.transpose(0, 2, 1, 3)                                   # (B,Hq,1,D)
    out_cmp = sdpa(qh, repeat_kv(kc_all, rep).transpose(0, 2, 1, 3),
                   repeat_kv(vc_all, rep).transpose(0, 2, 1, 3),
                   mask_to_bias(blk_ok[:, None, None, :]))
    qg = q1.reshape(B, 1, Hkv, Hq // Hkv, D)
    s = jnp.einsum("bmkrd,bnkd->bkn", qg.astype(jnp.float32),
                   kc_all.astype(jnp.float32),
                   preferred_element_type=jnp.float32) / (D ** 0.5)
    s = jnp.where(blk_ok[:, None, :], s, NEG_INF)
    return out_cmp, s


class _DensePoolOps:
    """Single-device pool access for the paged decode (default semantics).

    The decode core only touches the flat KV pools through these ops;
    the ``"sharded"`` backend swaps in row-partitioned versions (OOB-safe
    local gathers + ``psum``, OOB-dropped local scatters, a stats-merging
    ``cmp_attend``) so the SAME core runs with pools split across a mesh
    axis."""

    def __init__(self, gather):
        self._gather = gather

    def gather(self, pool, rows):
        # (R,Hkv,D), int rows → rows.shape + (Hkv,D)
        return self._gather(pool, rows)

    def gather_head(self, pool, rows, head_idx):
        # per-head row gather: rows (B,Hkv,k*,ell), head_idx broadcastable
        return pool[rows, head_idx]

    def scatter_rows(self, pool, rows, vals):
        return pool.at[rows].set(vals.astype(pool.dtype))

    def cmp_attend(self, k_pool, v_pool, rows, q1, blk_ok, rep):
        # gather the compressed rows, then the reference math
        return _cmp_attend_from_rows(self.gather(k_pool, rows),
                                     self.gather(v_pool, rows),
                                     q1, blk_ok, rep)


def nsa_causal_decode_paged(params, q1, k1, v1, cache: dict,
                            table: jnp.ndarray, lengths: jnp.ndarray, *,
                            cfg: BSAConfig, page: int,
                            x1: jnp.ndarray | None = None,
                            _pool_ops=None):
    """One decode step over PAGED per-slot caches.

    q1: (B,1,Hq,D); k1,v1: (B,1,Hkv,D) for slot b's NEW token at position
    ``lengths[b]``.  ``cache`` holds flat pools (init_paged_decode_cache);
    ``table``: (B, n_pages) int32 block table mapping slot-local pages to
    pool blocks (unallocated / retired entries point at the trash block);
    ``lengths``: (B,) int32 per-slot token counts.  ``page`` is static.

    Correctness leans on the host allocator's contract: the page containing
    position ``lengths[b]`` is EXCLUSIVELY owned by slot b (refcount 1 —
    copy-on-write happens host-side before the step), so the token scatter
    and the conditional compressed-row read-modify-write never collide
    across slots; inactive slots' tables are all-trash, so their writes land
    in the trash block (collisions there are harmless).

    Returns (out (B,1,Hq,D), new_cache) — lengths are NOT advanced here;
    the host controller owns them.
    """
    backend = resolve_branch_backends(cfg)["cmp"]
    if _pool_ops is None and getattr(backend, "is_sharded_backend", False):
        # re-enter through shard_map with row-partitioned pools; the inner
        # call comes back here with _pool_ops set, so no recursion
        from repro.distributed import sharded_backend as _sb
        return _sb.sharded_paged_decode(backend, params, q1, k1, v1, cache,
                                        table, lengths, cfg=cfg, page=page,
                                        x1=x1)
    ops = _pool_ops if _pool_ops is not None else _DensePoolOps(
        get_paged_gather(backend))
    B, _, Hq, D = q1.shape
    Hkv = k1.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block
    w = cfg.effective_local_window
    n_pages = table.shape[1]
    cpp = page // ell                         # compressed rows per page
    nb_max = n_pages * cpp
    capacity = n_pages * page
    if capacity < 2 * w:
        raise ValueError(f"slot capacity {capacity} < 2×local window {w}")
    t = lengths                               # (B,) position of each new token

    def row_of(pos):
        # (B, L) token positions → (B, L) token-pool rows via the table
        blk = jnp.take_along_axis(table, pos // page, axis=1)
        return blk * page + pos % page

    def crow_of(blk_idx):
        # (B, L) φ-block indices → (B, L) compressed-pool rows
        blk = jnp.take_along_axis(table, blk_idx // cpp, axis=1)
        return blk * cpp + blk_idx % cpp

    # --- cache update (token level): scatter each slot's new token ---
    wrow = row_of(t[:, None])[:, 0]                                 # (B,)
    k_pool = ops.scatter_rows(cache["k"], wrow, k1[:, 0])
    v_pool = ops.scatter_rows(cache["v"], wrow, v1[:, 0])

    # --- compressed update: slots whose new token completes a φ-block ---
    blk_id = t // ell
    complete = (t + 1) % ell == 0                                   # (B,)
    brows = row_of(blk_id[:, None] * ell + jnp.arange(ell)[None, :])  # (B,ell)
    new_kc = phi_apply(params["phi_k"], ops.gather(k_pool, brows), None, cfg)
    new_vc = phi_apply(params["phi_v"], ops.gather(v_pool, brows), None, cfg)
    crow = crow_of(blk_id[:, None])[:, 0]                           # (B,)
    # read-modify-write keeps non-completing slots' rows unchanged without
    # a per-slot conditional scatter (their row is exclusively owned)
    sel = complete[:, None, None]
    kc_val = jnp.where(sel, new_kc[:, 0].astype(cache["k_cmp"].dtype),
                       ops.gather(cache["k_cmp"], crow))
    vc_val = jnp.where(sel, new_vc[:, 0].astype(cache["v_cmp"].dtype),
                       ops.gather(cache["v_cmp"], crow))
    k_cmp = ops.scatter_rows(cache["k_cmp"], crow, kc_val)
    v_cmp = ops.scatter_rows(cache["v_cmp"], crow, vc_val)

    # --- local branch: per-slot blocked window [max(t//w-1,0)·w, t] ---
    start = jnp.maximum(t // w - 1, 0) * w                          # (B,)
    pos = start[:, None] + jnp.arange(2 * w)[None, :]               # (B,2w)
    win_valid = pos <= t[:, None]
    # invalid positions still index allocated-or-trash pages (w | page), so
    # the gather is safe; the bias kills their contribution
    k_win = ops.gather(k_pool, row_of(pos))                         # (B,2w,Hkv,D)
    v_win = ops.gather(v_pool, row_of(pos))
    qh = q1.transpose(0, 2, 1, 3)                                   # (B,Hq,1,D)
    out_local = sdpa(qh, repeat_kv(k_win, rep).transpose(0, 2, 1, 3),
                     repeat_kv(v_win, rep).transpose(0, 2, 1, 3),
                     mask_to_bias(win_valid[:, None, None, :]))

    # --- compression branch: all complete blocks strictly before t ---
    n_complete = (t + 1) // ell
    # blocks ending exactly AT t are excluded (strictly before t)
    blk_ok = jnp.arange(nb_max)[None, :] < jnp.where(
        complete, n_complete - 1, n_complete)[:, None]              # (B,NB)
    call = crow_of(jnp.broadcast_to(jnp.arange(nb_max)[None, :], (B, nb_max)))
    # one hook covers the compressed-row consumption: the dense ops gather
    # the rows and run the reference math; the sharded ops attend locally
    # owned rows and merge (m, l, acc) stats instead of moving row values
    out_cmp, s = ops.cmp_attend(k_cmp, v_cmp, call, q1, blk_ok, rep)

    # --- selection branch (scores ``s`` (B,Hkv,NB) from cmp_attend) ---
    if cfg.force_first_block:
        s = s.at[..., 0].add(jnp.where(blk_ok[:, 0], -NEG_INF, 0.0)[:, None])
    k_star = min(cfg.top_k, nb_max)
    top_vals, top_idx = jax.lax.top_k(s, k_star)                    # (B,Hkv,k*)
    sel_valid = top_vals > NEG_INF / 2
    L = k_star * ell
    ig = jnp.where(sel_valid, top_idx, 0)
    # per-head block choices → per-head token rows; the trailing head index
    # keeps the gather at k*·ℓ rows per (slot, head) instead of Hkv× that
    sel_pos = ig[..., None] * ell + jnp.arange(ell)                 # (B,Hkv,k*,ell)
    srows = row_of(sel_pos.reshape(B, Hkv * L)).reshape(B, Hkv, k_star, ell)
    head_idx = jnp.arange(Hkv)[None, :, None, None]
    kg = ops.gather_head(k_pool, srows, head_idx).reshape(B, Hkv, L, D)
    vg = ops.gather_head(v_pool, srows, head_idx).reshape(B, Hkv, L, D)
    key_valid = jnp.broadcast_to(sel_valid[..., None],
                                 (B, Hkv, k_star, ell)).reshape(B, Hkv, 1, L)
    qh2 = q1.reshape(B, 1, Hkv, rep, D).transpose(0, 2, 3, 1, 4).reshape(B, Hkv, rep, D)
    logits = jnp.einsum("bkrd,bkld->bkrl", qh2, kg,
                        preferred_element_type=jnp.float32) / (D ** 0.5)
    logits = logits + mask_to_bias(key_valid[:, :, 0][:, :, None, :])
    mx = jnp.maximum(logits.max(-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - mx)
    p = jnp.where(logits <= NEG_INF / 2, 0.0, p)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    out_slc = jnp.einsum("bkrl,bkld->bkrd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
    out_slc = out_slc.reshape(B, Hq, 1, D)

    gates = gate_values(params["gates"], cfg, x1, Hq)               # (B,1,H,1) or (1,1,H,1)
    gt = {b: jnp.moveaxis(gates[b], 2, 1) for b in gates}           # → (.,H,1,1)
    out = (gt["ball"] * out_local.astype(jnp.float32)
           + gt["cmp"] * out_cmp.astype(jnp.float32)
           + gt["slc"] * out_slc.astype(jnp.float32))
    out = out.transpose(0, 2, 1, 3).astype(q1.dtype)                # (B,1,Hq,D)

    new_cache = {"k": k_pool, "v": v_pool, "k_cmp": k_cmp, "v_cmp": v_cmp}
    return out, new_cache


def nsa_causal_decode(params, q1, k1, v1, cache: dict, *, cfg: BSAConfig,
                      x1: jnp.ndarray | None = None):
    """One decode step (dense cache — the lockstep layout).

    q1: (B,1,Hq,D); k1,v1: (B,1,Hkv,D) for the NEW token at position
    ``cache['length']``.  Returns (out (B,1,Hq,D), new_cache).
    Cost per token: O(w) local + O(S/ℓ) compression + O(k*·ℓ) selection.

    The dense (B, max_len, ·) cache is addressed as a degenerate paged
    layout — one page of ``max_len`` tokens per slot, identity block table,
    one shared length — so lockstep and continuous-batching decode share one
    numeric core (``nsa_causal_decode_paged``) and the decode-parity tests
    pin both at once.
    """
    B = q1.shape[0]
    S_max = cache["k"].shape[1]
    Hkv, D = cache["k"].shape[2], cache["k"].shape[3]
    nb = cache["k_cmp"].shape[1]
    t = cache["length"]
    pools = {
        "k": cache["k"].reshape(B * S_max, Hkv, D),
        "v": cache["v"].reshape(B * S_max, Hkv, D),
        "k_cmp": cache["k_cmp"].reshape(B * nb, Hkv, D),
        "v_cmp": cache["v_cmp"].reshape(B * nb, Hkv, D),
    }
    table = jnp.arange(B, dtype=jnp.int32)[:, None]        # slot b ↔ block b
    lengths = jnp.broadcast_to(t, (B,))
    out, pools = nsa_causal_decode_paged(params, q1, k1, v1, pools, table,
                                         lengths, cfg=cfg, page=S_max, x1=x1)
    new_cache = {
        "k": pools["k"].reshape(B, S_max, Hkv, D),
        "v": pools["v"].reshape(B, S_max, Hkv, D),
        "k_cmp": pools["k_cmp"].reshape(B, nb, Hkv, D),
        "v_cmp": pools["v_cmp"].reshape(B, nb, Hkv, D),
        "length": t + 1,
    }
    return out, new_cache
