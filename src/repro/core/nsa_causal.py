"""Causal BSA/NSA for 1-D token sequences (the LM-architecture backend).

For 1-D sequences the ball tree degenerates to contiguous blocks, so BSA
reduces to NSA with a *blocked local window* instead of the ball branch:

  * ``ball`` → blocked local attention: each query block (size w) attends
    causally within itself plus fully to the previous block (effective
    receptive window w..2w).  This replaces NSA's per-token sliding window
    with the hardware-aligned blocked equivalent (same trick Longformer /
    block-local FlashAttention use on TPU).
  * ``cmp``  → φ-compressed KV blocks; query t attends to every block that
    ends strictly before t.
  * ``slc``  → top-k *strictly past* blocks per query group (group-causal:
    a block is selectable iff it ends before the group starts, so one
    selection is causally valid for every query in the group).  The current
    block is covered by the local branch (NSA instead force-selects it; we
    document this deviation in DESIGN.md — the local branch already attends
    to it exactly).  ``force_first_block`` keeps NSA's always-select-initial
    -block behaviour.

Both a full-sequence train path and an incremental decode path (KV cache +
compressed-KV cache) are provided.  The decode path is O(w + S/ℓ + k*ℓ)
per token — sub-quadratic end-to-end, which is what makes ``long_500k``
serveable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import accepts_kwarg, get_combine, resolve_branch_backends
from repro.core.branches import (
    NEG_INF,
    block_validity,
    diag_scores,
    gate_values,
    gates_init,
    mask_to_bias,
    phi_apply,
    phi_init,
    repeat_kv,
    score_dtype_cast,
    sdpa,
)
from repro.core.config import BSAConfig

__all__ = [
    "nsa_init",
    "nsa_causal_attention",
    "init_decode_cache",
    "nsa_causal_decode",
    "local_window_attention_ref",
]


def nsa_init(key, cfg: BSAConfig, *, n_heads: int, n_kv_heads: int, head_dim: int,
             d_model: int, param_dtype=jnp.float32) -> dict:
    kk, kv, kq, kg = jax.random.split(key, 4)
    params = {
        "phi_k": phi_init(kk, cfg, head_dim, param_dtype=param_dtype),
        "phi_v": phi_init(kv, cfg, head_dim, param_dtype=param_dtype),
        "gates": gates_init(kg, cfg, n_heads, d_model, param_dtype=param_dtype),
    }
    if cfg.query_cmp_selection:
        params["phi_q"] = phi_init(kq, cfg, head_dim, param_dtype=param_dtype)
    return params


# ---------------------------------------------------------------------------
# Local branch — blocked causal window
# ---------------------------------------------------------------------------

def local_window_attention_ref(q, k, v, window: int, mask=None,
                               chunk_blocks: int = 0, block_seg=None):
    """Blocked local causal attention (pure-jnp reference).

    q,k,v: (B, N, H, D) with equal head counts.  Query block i attends to
    block i (causal) and block i-1 (full).  ``mask``: (B, N) bool key
    validity (True = real token) for packed ragged batches, or None.
    ``chunk_blocks`` > 0 bounds temp memory via lax.map tiles over blocks.
    ``block_seg``: (nb,) int32 per-BLOCK segment ids (packed-varlen layout,
    offsets multiples of ``window``) — the previous-block half is masked off
    whenever block i-1 belongs to a different segment, so windows never leak
    across sample boundaries."""
    B, N, H, D = q.shape
    w = window
    assert N % w == 0, f"N={N} not a multiple of local window {w}"
    nb = N // w
    qb = q.reshape(B, nb, w, H, D).transpose(0, 1, 3, 2, 4)        # (B,nb,H,w,D)
    kb = k.reshape(B, nb, w, H, D).transpose(0, 1, 3, 2, 4)
    vb = v.reshape(B, nb, w, H, D).transpose(0, 1, 3, 2, 4)
    # previous block (block -1 is zeros, fully masked)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kcat = jnp.concatenate([kprev, kb], axis=3)                     # (B,nb,H,2w,D)
    vcat = jnp.concatenate([vprev, vb], axis=3)
    qi = jnp.arange(w)[:, None]
    ki = jnp.arange(2 * w)[None, :]
    causal = ki <= qi + w                                           # allow prev + self-causal
    first = jnp.concatenate([jnp.zeros((w, w), bool), jnp.tril(jnp.ones((w, w), bool))], axis=1)
    bias = mask_to_bias(causal)                                     # (w, 2w)
    bias_first = mask_to_bias(first)
    biases = jnp.where((jnp.arange(nb) == 0)[:, None, None], bias_first[None], bias[None])
    biases = biases[None, :, None]                                  # (1,nb,1,w,2w)
    if block_seg is not None:
        # kill the prev-block half where block i-1 is a different segment
        prev_ok = jnp.concatenate([jnp.zeros((1,), bool),
                                   block_seg[1:] == block_seg[:-1]])
        prev_allow = jnp.concatenate(
            [jnp.broadcast_to(prev_ok[:, None], (nb, w)),
             jnp.ones((nb, w), bool)], axis=1)                      # (nb,2w)
        biases = biases + mask_to_bias(prev_allow)[None, :, None, None, :]
    if mask is not None:
        mb = mask.reshape(B, nb, w)
        mprev = jnp.concatenate([jnp.ones_like(mb[:, :1]), mb[:, :-1]], axis=1)
        mcat = jnp.concatenate([mprev, mb], axis=2)                 # (B,nb,2w)
        biases = biases + mask_to_bias(mcat[:, :, None, None, :])

    if chunk_blocks and nb % chunk_blocks == 0 and nb > chunk_blocks:
        nc = nb // chunk_blocks
        resh = lambda t: t.reshape(t.shape[0], nc, chunk_blocks, *t.shape[2:]) \
                          .transpose(1, 0, *range(2, t.ndim + 1))
        out = jax.lax.map(jax.checkpoint(lambda t: sdpa(t[0], t[1], t[2], t[3])),
                          (resh(qb), resh(kcat), resh(vcat),
                           resh(jnp.broadcast_to(biases, (B,) + biases.shape[1:]))))
        out = out.transpose(1, 0, *range(2, out.ndim)).reshape(B, nb, H, w, D)
    else:
        out = sdpa(qb, kcat, vcat, biases)                          # (B,nb,H,w,D)
    return out.transpose(0, 1, 3, 2, 4).reshape(B, N, H, D)


def _local_branch(q, k, v, mask, cfg: BSAConfig, backend):
    # GQA-native: un-repeated K/V — the backend owns the group strategy
    return backend.local_window(q, k, v, window=cfg.effective_local_window,
                                mask=mask, chunk_tokens=cfg.jnp_chunk_tokens)


# ---------------------------------------------------------------------------
# Train-time causal NSA
# ---------------------------------------------------------------------------

def nsa_causal_attention(params, q, k, v, *, cfg: BSAConfig,
                         mask: jnp.ndarray | None = None,
                         x: jnp.ndarray | None = None,
                         return_aux: bool = False):
    """Causal BSA.  q: (B,N,Hq,D); k,v: (B,N,Hkv,D)."""
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block

    # precision contract: under score_dtype="bfloat16" the branch inputs go
    # in bf16 (kernels keep QK^T/PV operands bf16, accumulate fp32) and the
    # combined output is cast back to the caller's dtype at the end.
    in_dtype = q.dtype
    q, k, v = score_dtype_cast(cfg, q, k, v)

    bk = resolve_branch_backends(cfg)
    out_local = _local_branch(q, k, v, mask, cfg, bk["ball"])

    # --- compression (GQA-native: coarse K/V stay at Hkv heads) ---
    k_cmp = phi_apply(params["phi_k"], k, mask, cfg)                # (B,NB,Hkv,D)
    v_cmp = phi_apply(params["phi_v"], v, mask, cfg)
    blk_valid = block_validity(mask, B, N, ell)
    # block-causal rule (query t sees coarse key j iff block j ends before t)
    # is generated by the backend — in-kernel on pallas, bias on jnp.
    # q_valid is an occupancy HINT (padded query rows are masked by the
    # combine epilogue, so kernels may skip whole dead query tiles);
    # probed by signature so third-party backends keep working.
    kw = ({"q_valid": mask}
          if mask is not None and accepts_kwarg(bk["cmp"].flash, "q_valid")
          else {})
    out_cmp = bk["cmp"].flash(q, k_cmp, v_cmp, key_valid=blk_valid,
                              block_causal=True, ell=ell,
                              chunk_tokens=cfg.jnp_chunk_tokens, **kw)

    # --- selection ---
    out_slc, top_idx = _causal_selection(params, q, k, v, k_cmp, blk_valid,
                                         mask, cfg, bk["slc"])

    gates = gate_values(params["gates"], cfg, x, Hq)
    # fused epilogue: gate + sum + query-mask in one pass (see core/bsa.py)
    out = get_combine(bk["ball"])(
        (out_local, out_cmp, out_slc),
        (gates["ball"], gates["cmp"], gates["slc"]), mask).astype(in_dtype)
    if return_aux:
        return out, {"local": out_local, "cmp": out_cmp, "slc": out_slc,
                     "indices": top_idx, "gates": gates}
    return out


def _causal_selection(params, q, k, v, k_cmp, blk_valid, mask, cfg: BSAConfig,
                      backend):
    B, N, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block
    nb = N // ell
    g = cfg.group_size if cfg.group_size else 1

    # scores (shared diag_scores: one cast to cfg.score_dtype, fp32 accumulate)
    if cfg.query_cmp_selection and cfg.group_size:
        q_s = phi_apply(params["phi_q"], q, mask, cfg)              # (B,NB,Hq,D)
        s = diag_scores(q_s, k_cmp, rep, cfg.score_dtype)           # (B,NB,Hkv,NB)
        rows_per_group = max(g // ell, 1)
        G = nb // rows_per_group
        s = s.reshape(B, G, rows_per_group, Hkv, nb).mean(axis=2)
    else:
        s = diag_scores(q, k_cmp, rep, cfg.score_dtype)             # (B,N,Hkv,NB)
        if cfg.group_size:
            G = N // g
            s = s.reshape(B, G, g, Hkv, nb).mean(axis=2)
        else:
            G = N
    s = s / (D ** 0.5)

    tokens_per_group = N // s.shape[1]
    G = s.shape[1]
    grp_start = jnp.arange(G) * tokens_per_group
    blk_end = jnp.arange(nb) * ell + (ell - 1)
    causal = blk_end[None, :] < grp_start[:, None]                  # (G,NB): strictly past
    s = jnp.where(causal[None, :, None, :], s, NEG_INF)
    s = jnp.where(blk_valid[:, None, None, :], s, NEG_INF)
    if cfg.force_first_block:
        # NSA always selects the initial block (when causally valid)
        boost = jnp.where(causal[:, :1], -NEG_INF, 0.0)             # (G,1)
        s = s.at[..., 0].add(boost[None, :, None, 0])

    k_star = min(cfg.top_k, nb)
    top_vals, top_idx = jax.lax.top_k(s, k_star)                    # (B,G,Hkv,k*)
    sel_valid = top_vals > NEG_INF / 2

    # gather & attend (strictly-past blocks ⇒ no intra-block causal mask)
    out = backend.selection(q, k, v, top_idx, sel_valid, mask,
                            block_size=ell, group_size=N // G,
                            chunk_tokens=cfg.jnp_chunk_tokens)
    return out, top_idx


# ---------------------------------------------------------------------------
# Decode path (incremental, cached)
# ---------------------------------------------------------------------------

def init_decode_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                      cfg: BSAConfig, dtype=jnp.bfloat16) -> dict:
    w = cfg.effective_local_window
    if max_len < 2 * w or max_len % w:
        raise ValueError(f"max_len={max_len} must be a multiple of the local "
                         f"window {w} and at least 2×")
    nb = max_len // cfg.cmp_block
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "k_cmp": jnp.zeros((batch, nb, n_kv_heads, head_dim), dtype),
        "v_cmp": jnp.zeros((batch, nb, n_kv_heads, head_dim), dtype),
        "length": jnp.zeros((), jnp.int32),   # tokens already in cache
    }


def nsa_causal_decode(params, q1, k1, v1, cache: dict, *, cfg: BSAConfig,
                      x1: jnp.ndarray | None = None):
    """One decode step.

    q1: (B,1,Hq,D); k1,v1: (B,1,Hkv,D) for the NEW token at position
    ``cache['length']``.  Returns (out (B,1,Hq,D), new_cache).
    Cost per token: O(w) local + O(S/ℓ) compression + O(k*·ℓ) selection.
    """
    B, _, Hq, D = q1.shape
    Hkv = k1.shape[2]
    rep = Hq // Hkv
    ell = cfg.cmp_block
    w = cfg.effective_local_window
    t = cache["length"]                                             # position of new token
    S_max = cache["k"].shape[1]
    nb_max = S_max // ell

    # --- cache update (token level) ---
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype),
                                           (0, t, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype),
                                           (0, t, 0, 0))

    # --- compressed cache update: when the new token completes a block ---
    blk_id = t // ell
    blk_start = blk_id * ell
    complete = (t + 1) % ell == 0
    last_block_k = jax.lax.dynamic_slice(
        k_cache, (0, blk_start, 0, 0), (B, ell, Hkv, D))
    last_block_v = jax.lax.dynamic_slice(
        v_cache, (0, blk_start, 0, 0), (B, ell, Hkv, D))
    new_kc = phi_apply(params["phi_k"], last_block_k, None, cfg)    # (B,1,Hkv,D)
    new_vc = phi_apply(params["phi_v"], last_block_v, None, cfg)
    k_cmp = jnp.where(
        complete,
        jax.lax.dynamic_update_slice(cache["k_cmp"], new_kc.astype(cache["k_cmp"].dtype),
                                     (0, blk_id, 0, 0)),
        cache["k_cmp"])
    v_cmp = jnp.where(
        complete,
        jax.lax.dynamic_update_slice(cache["v_cmp"], new_vc.astype(cache["v_cmp"].dtype),
                                     (0, blk_id, 0, 0)),
        cache["v_cmp"])

    # --- local branch: mirror the train-time BLOCKED window exactly ---
    # token t lives in block b = t//w and attends to block b (causal) plus
    # block b-1 (full) ⇒ the attendable range is [max(b-1,0)·w, t].
    blk_lw = t // w
    start = jnp.maximum(blk_lw - 1, 0) * w
    k_win = jax.lax.dynamic_slice(k_cache, (0, start, 0, 0), (B, 2 * w, Hkv, D))
    v_win = jax.lax.dynamic_slice(v_cache, (0, start, 0, 0), (B, 2 * w, Hkv, D))
    pos = start + jnp.arange(2 * w)
    win_valid = pos <= t                                            # (2w,)
    qh = q1.transpose(0, 2, 1, 3)                                   # (B,Hq,1,D)
    out_local = sdpa(qh, repeat_kv(k_win, rep).transpose(0, 2, 1, 3),
                     repeat_kv(v_win, rep).transpose(0, 2, 1, 3),
                     mask_to_bias(win_valid[None, None, None, :]))

    # --- compression branch: all complete blocks strictly before t ---
    n_complete = (t + 1) // ell                                     # after this token
    blk_ok = jnp.arange(nb_max) < jnp.where(complete, n_complete - 1,
                                            n_complete)             # strictly past
    # blocks that end exactly at t are excluded (strictly before t);
    # `complete` means block blk_id ends AT t → not yet attendable by t itself.
    out_cmp = sdpa(qh, repeat_kv(k_cmp, rep).transpose(0, 2, 1, 3),
                   repeat_kv(v_cmp, rep).transpose(0, 2, 1, 3),
                   mask_to_bias(blk_ok[None, None, None, :]))

    # --- selection branch ---
    qg = q1.reshape(B, 1, Hkv, rep, D)
    s = jnp.einsum("bmkrd,bnkd->bkn", qg.astype(jnp.float32),
                   k_cmp.astype(jnp.float32),
                   preferred_element_type=jnp.float32) / (D ** 0.5)  # (B,Hkv,NB)
    s = jnp.where(blk_ok[None, None, :], s, NEG_INF)
    if cfg.force_first_block:
        s = s.at[..., 0].add(jnp.where(blk_ok[0], -NEG_INF, 0.0))
    k_star = min(cfg.top_k, nb_max)
    top_vals, top_idx = jax.lax.top_k(s, k_star)                    # (B,Hkv,k*)
    sel_valid = top_vals > NEG_INF / 2
    # batched take_along_axis with (B, Hkv) as batch dims — keeps sharded
    # head (or sequence) cache axes local under GSPMD (see branches.py)
    L = k_star * ell
    ig = jnp.where(sel_valid, top_idx, 0)
    kbh = k_cache.reshape(B, nb_max, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    vbh = v_cache.reshape(B, nb_max, ell, Hkv, D).transpose(0, 3, 1, 2, 4)
    kg = jnp.take_along_axis(kbh.reshape(B, Hkv, nb_max, ell * D),
                             ig[..., None], axis=2).reshape(B, Hkv, L, D)
    vg = jnp.take_along_axis(vbh.reshape(B, Hkv, nb_max, ell * D),
                             ig[..., None], axis=2).reshape(B, Hkv, L, D)
    key_valid = jnp.broadcast_to(sel_valid[..., None],
                                 (B, Hkv, k_star, ell)).reshape(B, Hkv, 1, L)
    qh2 = q1.reshape(B, 1, Hkv, rep, D).transpose(0, 2, 3, 1, 4).reshape(B, Hkv, rep, D)
    logits = jnp.einsum("bkrd,bkld->bkrl", qh2, kg,
                        preferred_element_type=jnp.float32) / (D ** 0.5)
    logits = logits + mask_to_bias(key_valid[:, :, 0][:, :, None, :])
    mx = jnp.maximum(logits.max(-1, keepdims=True), NEG_INF / 2)
    p = jnp.exp(logits - mx)
    p = jnp.where(logits <= NEG_INF / 2, 0.0, p)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    out_slc = jnp.einsum("bkrl,bkld->bkrd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
    out_slc = out_slc.reshape(B, Hq, 1, D)

    gates = gate_values(params["gates"], cfg, x1, Hq)               # (B,1,H,1) or (1,1,H,1)
    gt = {b: jnp.moveaxis(gates[b], 2, 1) for b in gates}           # → (.,H,1,1)
    out = (gt["ball"] * out_local.astype(jnp.float32)
           + gt["cmp"] * out_cmp.astype(jnp.float32)
           + gt["slc"] * out_slc.astype(jnp.float32))
    out = out.transpose(0, 2, 1, 3).astype(q1.dtype)                # (B,1,Hq,D)

    new_cache = {"k": k_cache, "v": v_cache, "k_cmp": k_cmp, "v_cmp": v_cmp,
                 "length": t + 1}
    return out, new_cache
