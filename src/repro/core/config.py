"""Configuration for Ball Sparse Attention (paper Appendix A defaults)."""

from __future__ import annotations

import dataclasses
import warnings


@dataclasses.dataclass(frozen=True)
class BSAConfig:
    """Hyperparameters of Ball Sparse Attention.

    Defaults follow the paper (Appendix A, Table 4): ball 256, compression
    block ℓ=8 with stride 8, selection block 8, top-k 4, group size 8.
    """

    ball_size: int = 256            # m — BTA ball size (power of two)
    cmp_block: int = 8              # ℓ — compression block length (stride = ℓ)
    slc_block: int = 8              # selection block length (paper uses = ℓ)
    top_k: int = 4                  # k* — number of selected blocks
    group_size: int = 8             # g — query group for shared selection (0 ⇒ off)
    query_cmp_selection: bool = True   # Eq. 13–14: score with pooled queries
    group_compression: bool = False    # Eq. 15: pooled-query compression branch
    phi: str = "mean"               # φ pooling: "mean" | "mlp"
    gate_mode: str = "scalar"       # σ(γ_b): "scalar" (per head) | "token" (input-dep.)
    mask_own_ball: bool = True      # §3.2: selection ignores blocks in own ball
    # --- causal-LM variant knobs (core/nsa_causal.py) ---
    local_window: int = 0           # sliding-window length; 0 ⇒ ball_size
    force_first_block: bool = True  # NSA: always select the initial block
    # --- implementation ---
    backend: str = "auto"           # named attention backend (core/backend.py):
                                    # "jnp" | "pallas" | "interpret" | "auto"
                                    # (pallas on TPU, jnp elsewhere) | plug-in
    backend_overrides: tuple = ()   # per-branch redirects, e.g.
                                    # {"slc": "jnp"} — keys "ball"|"cmp"|"slc"
                                    # (dict accepted; stored as sorted items)
    jnp_chunk_tokens: int = 0       # jnp backend: query-tile size bounding
                                    # temp memory (0 = off); kernels ignore it
    score_dtype: str = "float32"    # selection-scoring einsum operand dtype
                                    # ("float32" | "bfloat16"): bf16 keeps
                                    # scoring on bf16 MXU paths instead of
                                    # silently upcasting activations; the
                                    # contraction always accumulates in fp32
    # DEPRECATED: pre-registry boolean.  Constructing with use_kernels=True/
    # False still works (maps to backend="pallas"/"jnp" + DeprecationWarning);
    # the stored field is normalised back to None so dataclasses.replace()
    # on other fields neither re-warns nor clobbers an explicit backend.
    use_kernels: bool | None = None

    def __post_init__(self):
        if isinstance(self.backend_overrides, dict):
            object.__setattr__(self, "backend_overrides",
                               tuple(sorted(self.backend_overrides.items())))
        for branch, name in self.backend_overrides:
            if branch not in ("ball", "cmp", "slc"):
                raise ValueError(f"backend_overrides key {branch!r} invalid "
                                 "(must be 'ball', 'cmp' or 'slc'; 'ball' also "
                                 "covers the causal local-window branch)")
            if not isinstance(name, str):
                raise ValueError(f"backend_overrides[{branch!r}] must be a "
                                 f"backend NAME, got {type(name).__name__}")
        if self.use_kernels is not None:
            mapped = "pallas" if self.use_kernels else "jnp"
            note = ""
            if self.backend not in ("auto", mapped):
                # backend can't distinguish "explicitly passed" from "stored
                # by an earlier shim mapping", so the deprecated flag always
                # wins — but never silently.
                note = (f" (overriding backend={self.backend!r}; drop "
                        "use_kernels to keep an explicit backend)")
            warnings.warn(
                "BSAConfig(use_kernels=...) is deprecated; use "
                f"backend={mapped!r} — see repro.core.backend{note}",
                DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "backend", mapped)
            object.__setattr__(self, "use_kernels", None)
        # Normalise dtype-like spellings (jnp.bfloat16, np.dtype("float32"),
        # "bf16"…) to the canonical name before validating, so
        # replace(cfg, score_dtype=jnp.bfloat16) works.
        sd = self.score_dtype
        if not isinstance(sd, str) or sd not in ("float32", "bfloat16"):
            try:
                import numpy as _np
                sd = _np.dtype(sd).name
            except TypeError as e:
                raise ValueError(
                    f"score_dtype {self.score_dtype!r} is not a dtype: pass "
                    '"float32", "bfloat16", or an equivalent dtype object '
                    "(e.g. jnp.bfloat16, np.float32)") from e
            object.__setattr__(self, "score_dtype", sd)
        if sd not in ("float32", "bfloat16"):
            raise ValueError(f"score_dtype {self.score_dtype!r} must be "
                             '"float32" or "bfloat16" — as the string, or as '
                             "an equivalent dtype object (e.g. jnp.bfloat16) "
                             "(the tested, TPU-native scoring dtypes)")
        if self.ball_size & (self.ball_size - 1):
            raise ValueError("ball_size must be a power of two")
        if self.slc_block != self.cmp_block:
            raise ValueError("selection block must equal compression block "
                             "(paper setting; keeps score→block mapping trivial)")
        if self.ball_size % self.cmp_block:
            raise ValueError("cmp_block must divide ball_size")
        if self.group_size and self.ball_size % self.group_size:
            raise ValueError("group_size must divide ball_size")
        if self.group_size and self.query_cmp_selection and (
                self.group_size % self.cmp_block and self.cmp_block % self.group_size):
            raise ValueError("group_size and cmp_block must nest")

    @property
    def effective_local_window(self) -> int:
        return self.local_window or self.ball_size
