"""Pluggable attention-backend registry — the kernel/reference seam as API.

Every attention entry point (``bsa_attention``, ``nsa_causal_attention``,
``erwin_attention``, ``full_attention``) executes its hot loops through a
:class:`Backend`: an object providing the four primitive attention ops

  * ``ball``          — full attention inside contiguous balls (BTA),
  * ``flash``         — streaming-softmax q vs arbitrary-length K/V with the
                        BSA mask modes (key validity, token-causal,
                        block-causal),
  * ``local_window``  — blocked local causal attention (the LM ball branch),
  * ``selection``     — group top-k gathered-block attention (GQA-aware).

All four ops are differentiable (the Pallas implementations carry fused
``jax.custom_vjp`` backwards, the jnp ones differentiate natively), take the
``core`` tensor convention — q ``(B, N, Hq, D)``, k/v ``(B, L, Hkv, D)``
with ``Hq = Hkv · rep`` (GQA-NATIVE: callers never head-repeat K/V; each
backend owns its own GQA strategy — the Pallas kernels share one K/V fetch
per group, the jnp reference repeats internally to pin semantics), masks
``(B, L)`` bool with True = real token — and honour the shared logit-space
masking rules (``repro.numerics``), so backends are interchangeable without
call-site changes.

A backend MAY additionally provide the optional fused epilogue op

  * ``gated_combine(outs, gates, mask)`` — three branch outputs gated,
    summed and query-masked in one pass (``out = Σ g_b·out_b, masked``).

``bsa_attention`` / ``nsa_causal_attention`` resolve it via
:func:`get_combine`; backends without it transparently fall back to the jnp
reference (``branches.gated_combine_ref``), so pre-existing plug-ins keep
working unchanged.

Backends MAY also provide the optional PACKED-VARLEN entry points — the
offsets-based ragged layout of ``docs/varlen.md`` (clouds concatenated on
one unbatched ``(ΣNᵢ, H, D)`` axis, per-sample boundaries carried by an
``offsets`` array instead of dummy-padded batch slots):

  * ``ball_varlen(q, k, v, offsets, mask, *, ball_size, chunk_tokens=0)``
  * ``flash_varlen(q, k, v, q_offsets, k_offsets, *, key_valid=None,
    chunk_tokens=0)`` — separate query/key offsets (the compression branch
    attends packed tokens vs packed φ-blocks)
  * ``local_window_varlen(q, k, v, offsets, *, window, mask=None,
    chunk_tokens=0)``
  * ``selection_varlen(q, k, v, top_idx, sel_valid, offsets, mask, *,
    block_size, group_size, chunk_tokens=0)``

``bsa_attention_varlen`` resolves them via :func:`get_varlen`; backends
without them fall back to the jnp reference implementations (the parity
oracle), so pre-existing plug-ins keep working on packed batches too.

Built-ins:

  ``"jnp"``        pure-jnp reference (optionally memory-bounded via
                   ``chunk_tokens``),
  ``"pallas"``     the Pallas TPU kernels (interpret mode auto-detected on
                   non-TPU hosts, see ``kernels/common.should_interpret``),
  ``"interpret"``  the Pallas kernels FORCED into interpret mode — the
                   kernel bodies execute as Python everywhere (debugging /
                   CI parity legs),
  ``"auto"``       resolves to ``"pallas"`` on TPU, ``"jnp"`` otherwise.

Third-party/test backends plug in via :func:`register_backend`; anything
satisfying the :class:`Backend` protocol works (e.g. an instrumented
counting wrapper, a sharded implementation, a different accelerator).

Resolution precedence (weakest → strongest)::

    BSAConfig.backend  <  with use_backend("..."):  <  REPRO_ATTENTION_BACKEND

The environment variable and the context manager force ONE backend for all
branches (that is their point: CI legs and experiments override everything
below them).  Absent both, ``BSAConfig.backend`` is the base choice and
``BSAConfig.backend_overrides`` may redirect individual branches, e.g.
``BSAConfig(backend="pallas", backend_overrides={"slc": "jnp"})`` runs only
the selection branch on the reference path.  Branch keys are ``"ball"``
(which also governs the local-window branch of the causal variant),
``"cmp"`` and ``"slc"``.

Resolution happens at TRACE time (plain Python), so a jitted function bakes
in whatever backend was active when it was traced — re-trace (new jit or new
shapes) to switch.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Iterator, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.numerics import mask_to_bias

__all__ = [
    "Backend",
    "JnpBackend",
    "PallasBackend",
    "ENV_VAR",
    "DEFAULT_BACKEND",
    "BRANCH_KEYS",
    "register_backend",
    "get_backend",
    "list_backends",
    "use_backend",
    "resolve_backend",
    "resolve_backend_name",
    "resolve_branch_backends",
    "get_combine",
    "get_varlen",
    "get_paged_gather",
    "accepts_kwarg",
]

ENV_VAR = "REPRO_ATTENTION_BACKEND"
DEFAULT_BACKEND = "auto"
BRANCH_KEYS = ("ball", "cmp", "slc")


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class Backend(Protocol):
    """The four primitive attention ops a backend must provide.

    Shapes follow ``core``: q is (B, N, Hq, D); k/v are (B, L, Hkv, D) with
    ``Hq = Hkv · rep`` — ALL four ops are GQA-native (callers never repeat
    KV; query head ``h·rep + r`` belongs to KV head ``h``).  How a backend
    exploits the grouping is its own business: the Pallas kernels share one
    K/V fetch across the group's ``rep`` query heads, the jnp reference
    repeats KV internally (``branches.repeat_kv``) to pin semantics.
    ``chunk_tokens`` is a memory bound the jnp backend honours (query-tile
    ``lax.map``); kernel backends ignore it.  Every op must be
    differentiable in q, k, v.

    Backends may also provide the OPTIONAL fused epilogue
    ``gated_combine(outs, gates, mask)`` (not part of the required
    protocol); see :func:`get_combine`.
    """

    name: str

    def ball(self, q, k, v, mask, *, ball_size: int,
             chunk_tokens: int = 0) -> jnp.ndarray: ...

    def flash(self, q, k, v, *, key_valid=None, causal: bool = False,
              block_causal: bool = False, ell: int = 1,
              chunk_tokens: int = 0) -> jnp.ndarray: ...

    # Backends MAY additionally accept ``q_valid=None`` on ``flash`` — an
    # OPTIMIZATION-ONLY query-validity hint (rows of all-padding query tiles
    # may come back unspecified/zero; callers mask them downstream).  Callers
    # probe for it with :func:`accepts_kwarg`, so plug-ins without the kwarg
    # keep working unchanged.

    def local_window(self, q, k, v, *, window: int, mask=None,
                     chunk_tokens: int = 0) -> jnp.ndarray: ...

    def selection(self, q, k, v, top_idx, sel_valid, mask, *, block_size: int,
                  group_size: int, chunk_tokens: int = 0) -> jnp.ndarray: ...

    # Backends MAY additionally accept ``q_valid=None`` on ``selection`` —
    # unlike the flash hint this one is SEMANTIC when present: it supplies
    # query-side validity separately from the key-sized ``mask`` so
    # context-parallel callers can pass a local query slab (N) against the
    # full key set (L > N).  Probed with :func:`accepts_kwarg`; the
    # ``"sharded"`` backend only shards selection over inners that have it.


# ---------------------------------------------------------------------------
# Built-in: pure-jnp reference
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JnpBackend:
    """Reference implementations from ``core`` — run anywhere, differentiate
    natively, and serve as the parity oracle for every other backend.

    GQA is handled by MATERIALISING the head repetition
    (``branches.repeat_kv``) before the equal-head reference math — the
    semantic definition the kernel backends' shared-fetch layouts must
    match.  ``selection_attend`` is group-native already (shared block set
    per group is the algorithm), so it takes the un-repeated KV directly.
    """

    name: str = "jnp"

    @staticmethod
    def _rep(q, k, v):
        from repro.core.branches import repeat_kv
        rep = q.shape[2] // k.shape[2]
        return repeat_kv(k, rep), repeat_kv(v, rep)

    def ball(self, q, k, v, mask, *, ball_size, chunk_tokens=0):
        from repro.core.bsa import ball_attention_ref
        k, v = self._rep(q, k, v)
        cb = max(chunk_tokens // ball_size, 1) if chunk_tokens else 0
        return ball_attention_ref(q, k, v, mask, ball_size, chunk_balls=cb)

    def flash(self, q, k, v, *, key_valid=None, causal=False,
              block_causal=False, ell=1, chunk_tokens=0, q_valid=None):
        # q_valid is an optimization hint only — the reference computes every
        # row (its outputs on padded rows ARE the specified values)
        from repro.core.branches import chunked_q_attention, sdpa
        k, v = self._rep(q, k, v)
        if not causal:
            # chunked_q_attention owns the key-valid and block-causal bias
            # rules; chunk=0 is the dense one-shot path
            return chunked_q_attention(q, k, v, key_valid=key_valid,
                                       block_causal_ell=ell if block_causal else 0,
                                       chunk=chunk_tokens)
        B, N, H, D = q.shape
        L = k.shape[1]
        bias = jnp.zeros((1, 1, 1, L), jnp.float32)
        if key_valid is not None:
            bias = bias + mask_to_bias(key_valid[:, None, None, :])
        qi = jnp.arange(N)[:, None] + (L - N)       # align ends (cache decoding)
        ki = jnp.arange(L)[None, :]
        bias = bias + mask_to_bias((ki <= qi)[None, None])
        out = sdpa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                   v.transpose(0, 2, 1, 3), bias)
        return out.transpose(0, 2, 1, 3)

    def local_window(self, q, k, v, *, window, mask=None, chunk_tokens=0):
        from repro.core.nsa_causal import local_window_attention_ref
        k, v = self._rep(q, k, v)
        cb = max(chunk_tokens // window, 1) if chunk_tokens else 0
        return local_window_attention_ref(q, k, v, window, mask=mask,
                                          chunk_blocks=cb)

    def selection(self, q, k, v, top_idx, sel_valid, mask, *, block_size,
                  group_size, chunk_tokens=0, q_valid=None):
        from repro.core.branches import selection_attend
        return selection_attend(q, k, v, top_idx, sel_valid, mask,
                                block_size=block_size, chunk_tokens=chunk_tokens,
                                q_valid=q_valid)

    def gated_combine(self, outs, gates, mask):
        from repro.core.branches import gated_combine_ref
        return gated_combine_ref(outs, gates, mask)

    def paged_gather(self, pool, rows):
        # reference semantics for the paged-decode row gather: plain
        # advanced indexing (XLA lowers it to one dynamic-gather)
        return pool[rows]

    # -- packed-varlen (offsets-based) entry points: q (T,Hq,D); k/v (L,Hkv,D).
    # These ARE the parity oracle for kernel backends' varlen paths: segment
    # isolation is expressed as explicit logit bias on the reference math.

    def ball_varlen(self, q, k, v, offsets, mask, *, ball_size, chunk_tokens=0):
        # offsets are ball multiples by contract, so balls never straddle a
        # sample boundary — packed ball attention IS B=1 ball attention.
        return self.ball(q[None], k[None], v[None],
                         None if mask is None else mask[None],
                         ball_size=ball_size, chunk_tokens=chunk_tokens)[0]

    def flash_varlen(self, q, k, v, q_offsets, k_offsets, *, key_valid=None,
                     chunk_tokens=0):
        from repro.core.branches import chunked_q_attention
        from repro.numerics import segment_ids_from_offsets
        qb = q[None]
        kb, vb = self._rep(qb, k[None], v[None])
        q_seg = segment_ids_from_offsets(q_offsets, q.shape[0])
        k_seg = segment_ids_from_offsets(k_offsets, k.shape[0])
        return chunked_q_attention(
            qb, kb, vb,
            key_valid=None if key_valid is None else key_valid[None],
            chunk=chunk_tokens, q_seg=q_seg, k_seg=k_seg)[0]

    def local_window_varlen(self, q, k, v, offsets, *, window, mask=None,
                            chunk_tokens=0):
        from repro.core.nsa_causal import local_window_attention_ref
        from repro.numerics import segment_ids_from_offsets
        qb = q[None]
        kb, vb = self._rep(qb, k[None], v[None])
        seg = segment_ids_from_offsets(offsets, q.shape[0])
        blk_seg = seg.reshape(q.shape[0] // window, window)[:, 0]
        cb = max(chunk_tokens // window, 1) if chunk_tokens else 0
        return local_window_attention_ref(
            qb, kb, vb, window, mask=None if mask is None else mask[None],
            chunk_blocks=cb, block_seg=blk_seg)[0]

    def selection_varlen(self, q, k, v, top_idx, sel_valid, offsets, mask, *,
                         block_size, group_size, chunk_tokens=0):
        # cross-sample isolation lives in the SCORES (a group's candidate
        # blocks from other samples are NEG_INF → sel_valid False), so the
        # packed gather-attend is B=1 selection attention.
        return self.selection(q[None], k[None], v[None], top_idx[None],
                              sel_valid[None],
                              None if mask is None else mask[None],
                              block_size=block_size, group_size=group_size,
                              chunk_tokens=chunk_tokens)[0]


# ---------------------------------------------------------------------------
# Built-in: Pallas kernels (compiled on TPU, interpret elsewhere)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PallasBackend:
    """The fused Pallas kernel path (``repro.kernels.ops``).

    ``interpret=None`` auto-detects (compiled on TPU, interpret mode
    elsewhere — same rule as ``REPRO_PALLAS_INTERPRET``); ``interpret=True``
    is the ``"interpret"`` built-in, forcing the kernel bodies to execute as
    Python everywhere.  ``chunk_tokens`` is ignored: the kernels stream
    through VMEM tiles by construction.
    """

    name: str = "pallas"
    interpret: bool | None = None

    def ball(self, q, k, v, mask, *, ball_size, chunk_tokens=0):
        from repro.kernels import ops as kops
        return kops.ball_attention(q, k, v, mask, ball_size,
                                   interpret=self.interpret)

    def flash(self, q, k, v, *, key_valid=None, causal=False,
              block_causal=False, ell=1, chunk_tokens=0, q_valid=None):
        from repro.kernels import ops as kops
        assert not causal or k.shape[1] == q.shape[1], \
            "kernel path assumes aligned q/k for token-level causal"
        return kops.flash_attention(q, k, v, key_valid=key_valid, causal=causal,
                                    block_causal=block_causal, ell=ell,
                                    q_valid=q_valid, interpret=self.interpret)

    def local_window(self, q, k, v, *, window, mask=None, chunk_tokens=0):
        from repro.kernels import ops as kops
        return kops.local_window_attention(q, k, v, window, mask=mask,
                                           interpret=self.interpret)

    def selection(self, q, k, v, top_idx, sel_valid, mask, *, block_size,
                  group_size, chunk_tokens=0, q_valid=None):
        from repro.kernels import ops as kops
        return kops.selection_attention(q, k, v, top_idx, sel_valid, mask,
                                        block_size=block_size,
                                        group_size=group_size,
                                        interpret=self.interpret,
                                        q_valid=q_valid)

    def gated_combine(self, outs, gates, mask):
        from repro.kernels import ops as kops
        return kops.gated_combine(outs, gates, mask, interpret=self.interpret)

    def paged_gather(self, pool, rows):
        from repro.kernels import ops as kops
        return kops.paged_gather(pool, rows, interpret=self.interpret)

    # -- packed-varlen entry points (``kernels/ops.py`` wrappers; the flash
    # one runs the dedicated segment-masked tile-skipping varlen kernel) --

    def ball_varlen(self, q, k, v, offsets, mask, *, ball_size, chunk_tokens=0):
        from repro.kernels import ops as kops
        return kops.ball_attention_varlen(q, k, v, offsets, mask, ball_size,
                                          interpret=self.interpret)

    def flash_varlen(self, q, k, v, q_offsets, k_offsets, *, key_valid=None,
                     chunk_tokens=0):
        from repro.kernels import ops as kops
        return kops.flash_attention_varlen(q, k, v, q_offsets, k_offsets,
                                           key_valid=key_valid,
                                           interpret=self.interpret)

    def local_window_varlen(self, q, k, v, offsets, *, window, mask=None,
                            chunk_tokens=0):
        from repro.kernels import ops as kops
        return kops.local_window_attention_varlen(q, k, v, offsets, window,
                                                  mask=mask,
                                                  interpret=self.interpret)

    def selection_varlen(self, q, k, v, top_idx, sel_valid, offsets, mask, *,
                         block_size, group_size, chunk_tokens=0):
        from repro.kernels import ops as kops
        return kops.selection_attention_varlen(q, k, v, top_idx, sel_valid,
                                               offsets, mask,
                                               block_size=block_size,
                                               group_size=group_size,
                                               interpret=self.interpret)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Backend] = {}
_tls = threading.local()


def register_backend(name: str, backend: Backend, *,
                     overwrite: bool = False) -> Backend:
    """Register ``backend`` under ``name`` (the plug-in seam).

    ``name`` becomes valid everywhere a backend is named: ``BSAConfig``,
    ``backend_overrides``, ``use_backend(...)`` and ``REPRO_ATTENTION_BACKEND``.
    Re-registering an existing name requires ``overwrite=True``.  Returns the
    backend (decorator-friendly for classes with a zero-arg constructor).
    """
    if name == "auto":
        raise ValueError('"auto" is reserved (resolves to pallas on TPU, '
                         "jnp elsewhere)")
    if not isinstance(backend, Backend):
        missing = [op for op in ("ball", "flash", "local_window", "selection")
                   if not callable(getattr(backend, op, None))]
        raise TypeError(f"backend {name!r} does not satisfy the Backend "
                        f"protocol (missing ops: {missing})")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = backend
    return backend


def get_backend(name: str) -> Backend:
    """Look up a registered backend; ``"auto"`` resolves by platform."""
    if name == "auto":
        name = _auto_name()
    if name == "sharded" and name not in _REGISTRY:
        # lazy self-registration keeps core free of a distributed import
        # unless the multi-device backend is actually requested
        import repro.distributed.sharded_backend  # noqa: F401
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown attention backend {name!r}; registered: "
            f"{list_backends()} (register_backend() adds more, "
            f"${ENV_VAR} / use_backend() must name one of these)") from None


def list_backends() -> list[str]:
    """Registered backend names (excluding the ``"auto"`` alias)."""
    return sorted(_REGISTRY)


def _auto_name() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Resolution: config < context manager < environment
# ---------------------------------------------------------------------------

def _context_name() -> str | None:
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[Backend]:
    """Force backend ``name`` for every attention call traced in this block
    (this thread).  Nests; beaten only by ``REPRO_ATTENTION_BACKEND``."""
    backend = get_backend(name)          # fail fast on unknown names
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    stack.append(name)
    try:
        yield backend
    finally:
        stack.pop()


def resolve_backend_name(name: str | None = None) -> str:
    """Apply the precedence chain to a config-level ``name`` (may be None)."""
    env = os.environ.get(ENV_VAR)
    if env:
        return env
    ctx = _context_name()
    if ctx:
        return ctx
    return name or DEFAULT_BACKEND


def resolve_backend(name: "str | Backend | None" = None) -> Backend:
    """Resolve a config-level backend name to a Backend object.

    ``name`` may also be a Backend instance, which is returned as-is
    (programmatic escape hatch — bypasses context/env overrides).
    """
    if name is not None and not isinstance(name, str):
        return name
    return get_backend(resolve_backend_name(name))


def resolve_branch_backends(cfg) -> dict[str, Backend]:
    """Per-branch backends for ``bsa_attention`` / ``nsa_causal_attention``.

    Returns ``{"ball": Backend, "cmp": Backend, "slc": Backend}``.  An active
    environment/context override forces one backend for ALL branches;
    otherwise ``cfg.backend`` is the base and ``cfg.backend_overrides``
    redirects individual branches.
    """
    forced = os.environ.get(ENV_VAR) or _context_name()
    if forced:
        bk = get_backend(forced)
        return {b: bk for b in BRANCH_KEYS}
    base = cfg.backend or DEFAULT_BACKEND
    overrides = dict(cfg.backend_overrides or ())
    return {b: get_backend(overrides.get(b, base)) for b in BRANCH_KEYS}


def get_combine(backend: Backend):
    """The backend's fused gate epilogue, or the jnp reference if absent.

    ``gated_combine`` is an OPTIONAL backend op — plug-ins registered before
    it existed (or that simply don't care) fall back to
    ``branches.gated_combine_ref`` with identical semantics.
    """
    fn = getattr(backend, "gated_combine", None)
    if callable(fn):
        return fn
    from repro.core.branches import gated_combine_ref
    return gated_combine_ref


def accepts_kwarg(fn, name: str) -> bool:
    """Does ``fn`` accept keyword argument ``name``?

    The probe callers use before passing OPTIONAL protocol extensions (the
    ``q_valid`` hint on ``flash``) so third-party backends registered against
    the narrower signature keep working."""
    import inspect
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    p = sig.parameters.get(name)
    if p is not None:
        return p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
    return any(pp.kind == pp.VAR_KEYWORD for pp in sig.parameters.values())


def get_paged_gather(backend: Backend):
    """The backend's paged-cache row gather, or the jnp reference if absent.

    ``paged_gather(pool (R, Hkv, D), rows (B, L) int32) → (B, L, Hkv, D)``
    is the hot fetch of the paged decode path (``nsa_causal_decode_paged``):
    block-table-resolved pool rows pulled for the local window and the
    compressed branches.  An OPTIONAL protocol extension — plug-ins without
    it fall back to plain advanced indexing with identical semantics.
    """
    fn = getattr(backend, "paged_gather", None)
    if callable(fn):
        return fn
    return get_backend("jnp").paged_gather


def get_varlen(backend: Backend, op: str):
    """The backend's packed-varlen entry point ``<op>_varlen``, or the jnp
    reference's if the backend doesn't provide one.

    ``op`` is one of ``"ball"``, ``"flash"``, ``"local_window"``,
    ``"selection"``.  Like :func:`get_combine`, the varlen ops are OPTIONAL
    protocol extensions: a plug-in registered before the packed layout
    existed still serves packed batches through the jnp oracle with
    identical semantics (just without the kernel speed).
    """
    name = f"{op}_varlen"
    fn = getattr(backend, name, None)
    if callable(fn):
        return fn
    return getattr(get_backend("jnp"), name)


register_backend("jnp", JnpBackend())
register_backend("pallas", PallasBackend("pallas", None))
register_backend("interpret", PallasBackend("interpret", True))
