"""Paper Table 1 + Table 3 (ShapeNet): MSE / runtime / GFLOPs for BSA
variants vs Full Attention vs Erwin on the (synthetic) ShapeNet-Car task.

Reduced budget by default (CPU container); --full approaches paper scale.
"""

from __future__ import annotations

import argparse

from benchmarks.common import emit, train_eval

VARIANTS = [
    ("shapenet-bsa", "BSA"),
    ("shapenet-bsa-no-group", "BSA w/o group selection"),
    ("shapenet-bsa-group-cmp", "BSA w/ group compression"),
    ("shapenet-full", "Full Attention"),
    ("shapenet-erwin", "Erwin (BTA+coarsen)"),
]


def run(steps=60, n_layers=2, d_model=128, batch=2, n_points=896, variants=None):
    rows = []
    for arch, label in (variants or VARIANTS):
        r = train_eval(arch, steps=steps, n_layers=n_layers, d_model=d_model,
                       batch=batch, n_points=n_points)
        rows.append((arch, label, r))
        emit(f"table1/{arch}", r["us_per_call"],
             f"mse={r['mse']:.4f};gflops={r['gflops']:.2f};params={r['params']}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    if args.full:
        run(steps=args.steps or 2000, n_layers=18, d_model=256, batch=4,
            n_points=3586)
    else:
        run(steps=args.steps or 60)


if __name__ == "__main__":
    main()
