"""Paper Table 2: Elasticity stress-field RMSE — BSA vs Full Attention.
(Sequence length 972 → 1024; the paper notes BSA shows no advantage at this
scale, which the cost numbers reproduce.)"""

from __future__ import annotations

import argparse
import math

from benchmarks.common import emit, train_eval


def run(steps=60, n_layers=2, d_model=128, batch=2):
    rows = []
    for arch, label in [("elasticity-bsa", "BSA"), ("elasticity-full", "Full")]:
        r = train_eval(arch, steps=steps, n_layers=n_layers, d_model=d_model,
                       batch=batch, n_points=972, dataset="elasticity")
        rows.append((arch, label, r))
        emit(f"table2/{arch}", r["us_per_call"],
             f"rmse={math.sqrt(r['mse']):.4f};gflops={r['gflops']:.2f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    run(steps=args.steps)


if __name__ == "__main__":
    main()
