"""Paper Appendix B: compression-block × group-selection size ablation on
ShapeNet (reduced budget).  Reproduces the TREND of Table 5: ℓ=g=8 best,
ℓ=g=32 catastrophically worse (selection granularity too coarse)."""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import emit, train_eval
from repro.configs import get_config
from repro.configs.base import _REGISTRY  # noqa: PLC2701 — bench-local registration

GRID = [(4, 4), (8, 8), (16, 16), (32, 32), (4, 8), (8, 4)]


def run(steps=40, grid=None):
    rows = []
    base = get_config("shapenet-bsa")
    for ell, g in (grid or GRID):
        bsa = dataclasses.replace(base.bsa, cmp_block=ell, slc_block=ell,
                                  group_size=g)
        name = f"shapenet-bsa-l{ell}-g{g}"
        _REGISTRY[name] = lambda bsa=bsa, name=name: base.scaled(name=name, bsa=bsa)
        r = train_eval(name, steps=steps, n_layers=2, d_model=128, batch=2,
                       n_points=896)
        rows.append(((ell, g), r))
        emit(f"appb/l={ell},g={g}", r["us_per_call"], f"mse={r['mse']:.4f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    run(steps=args.steps)


if __name__ == "__main__":
    main()
