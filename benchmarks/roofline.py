"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the dry-run artifacts, dominant bottleneck, MODEL_FLOPS ratio.

  compute term    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16, v5e)
  memory term     = HLO_bytes_per_device / 819 GB/s HBM
  collective term = wire_bytes_per_device / 50 GB/s/link ICI (1-link, conservative)

HLO numbers are loop-WEIGHTED per-device values from launch/hlo_analysis
(cost_analysis counts while bodies once — calibrated in EXPERIMENTS §Dry-run).
`roofline fraction` = compute / max(terms): 1.0 ⇒ compute-bound (at roofline
under perfect comm/compute overlap); < 1 ⇒ the dominant term is the gap.

Reads results/dryrun/*.json; writes results/roofline.md.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from repro.models.api import model_api

_COUNT_CACHE = {}


def param_counts(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts (active: MoE experts scaled k/E,
    embeddings excluded from both — 6ND convention)."""
    if arch in _COUNT_CACHE:
        return _COUNT_CACHE[arch]
    mcfg = get_config(arch)
    api = model_api(mcfg)
    tree = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = active = 0
    for path, leaf in flat:
        names = [str(getattr(k, "key", k)) for k in path]
        n = 1
        for d in leaf.shape:
            n *= d
        if any(x in names for x in ("embed", "tok_embed", "lm_head")):
            continue
        total += n
        if any(x in names for x in ("w_gate", "w_up", "w_down")):
            Ep = max(mcfg.pad_experts_to, mcfg.n_experts)
            active += n * mcfg.experts_per_token / Ep
        else:
            active += n
    _COUNT_CACHE[arch] = (total, int(active))
    return _COUNT_CACHE[arch]


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D for training, 2·N_active·D for prefill/decode (global)."""
    shape = SHAPES[shape_name]
    _, active = param_counts(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch          # decode: 1 token/slot


def analyze_cell(rec: dict) -> dict:
    comp = rec["flops_per_device"] / PEAK_FLOPS_BF16
    mem = rec["traffic_bytes_per_device"] / HBM_BW
    coll = rec["collective_wire_bytes"] / ICI_BW_PER_LINK
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = rec["flops_per_device"] * rec["n_devices"]
    return {
        **rec,
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom,
        "roofline_fraction": comp / max(max(terms.values()), 1e-30),
        "model_flops": mf,
        "useful_ratio": mf / max(hlo_global, 1e-30),
    }


def load_cells(dry_dir: Path, mesh: str = "pod1") -> list[dict]:
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = dry_dir / f"{arch}__{shape}__{mesh}.json"
            if not p.exists():
                continue
            rec = json.loads(p.read_text())
            if rec.get("ok"):
                cells.append(analyze_cell(rec))
    return cells


def render_markdown(cells: list[dict]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | roofline frac | useful FLOP ratio | peak/dev GiB (tpu-est) | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']*1e3:.2f} | "
            f"{c['memory_s']*1e3:.2f} | {c['collective_s']*1e3:.2f} | "
            f"{c['dominant']} | {c['roofline_fraction']:.3f} | "
            f"{c['useful_ratio']:.3f} | {c['peak_bytes_tpu_est']/2**30:.2f} | "
            f"{'✓' if c['fits_hbm'] else '✗'} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()
    cells = load_cells(Path(args.dry_dir), args.mesh)
    md = render_markdown(cells)
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(md)
    print(md)
    # CSV lines for run.py
    for c in cells:
        print(f"roofline/{c['arch']}/{c['shape']},"
              f"{max(c['compute_s'], c['memory_s'], c['collective_s'])*1e6:.1f},"
              f"dom={c['dominant']};frac={c['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
