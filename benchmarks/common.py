"""Shared benchmark utilities: timing, CSV output, small training runs.

CPU-budget note: this container is a single CPU core; benchmarks default to
REDUCED settings (fewer layers/steps, subsampled clouds) that preserve the
paper's comparisons (same attention configs, same relative measurements).
Pass --full to the individual scripts for paper-scale runs.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in µs (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def forward_flops(api, batch) -> float:
    """Analytic-by-compiler FLOPs of one forward call (single device)."""
    try:
        lowered = jax.jit(api.forward).lower(
            jax.eval_shape(lambda k: api.init(k), jax.random.PRNGKey(0)),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        from repro.launch.hlo_analysis import HloModule
        return HloModule(lowered.compile().as_text()).dot_flops()
    except Exception:
        return float("nan")


def train_eval(arch: str, *, steps: int, n_layers: int, d_model: int,
               batch: int, n_points: int, seed: int = 0,
               dataset: str = "shapenet") -> dict:
    """Train a reduced config of ``arch`` and return test MSE + timings."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data import ElasticityDataset, ShapeNetCarDataset
    from repro.models.api import model_api
    from repro.runtime import Trainer, TrainerConfig

    mcfg = get_config(arch).scaled(
        n_layers=n_layers, d_model=d_model, n_heads=4, head_dim=d_model // 4,
        n_kv_heads=4, d_ff=2 * d_model)
    api = model_api(mcfg)
    if dataset == "shapenet":
        tr_ds = ShapeNetCarDataset("train", n_points=n_points)
        te_ds = ShapeNetCarDataset("test", n_points=n_points)
    else:
        tr_ds = ElasticityDataset("train")
        te_ds = ElasticityDataset("test")

    cfg = TrainerConfig(base_lr=1e-3, weight_decay=0.01, total_steps=steps,
                        warmup_steps=max(steps // 10, 1), log_every=10 ** 9)
    t = Trainer(api, cfg)
    params, _ = t.fit(tr_ds.batches(batch, seed=seed), steps=steps)

    fwd = jax.jit(api.forward)
    mse, n = 0.0, 0
    for i, b in enumerate(te_ds.batches(batch, shuffle=False, epochs=1)):
        if i >= 6:
            break
        b = {k: jnp.asarray(v) for k, v in b.items()}
        pred = fwd(params, b)
        m = b["mask"][..., None]
        mse += float((((pred - b["target"]) ** 2) * m).sum() / m.sum())
        n += 1
    bt = {k: jnp.asarray(v) for k, v in next(tr_ds.batches(batch, seed=1)).items()}
    us = time_fn(fwd, params, bt)
    fl = forward_flops(api, bt)
    return {"mse": mse / max(n, 1), "us_per_call": us, "gflops": fl / 1e9,
            "params": sum(x.size for x in jax.tree.leaves(params))}
