"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--quick|--full]
  python benchmarks/run.py --smoke     # CI: one tiny fwd+bwd kernel-path iter
"""

import argparse
import os
import sys
import traceback
from pathlib import Path

if __package__ in (None, ""):                    # `python benchmarks/run.py`
    _root = Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_root))
    if "repro" not in sys.modules:               # no editable install: use src/
        sys.path.insert(0, str(_root / "src"))


def smoke() -> None:
    """One tiny fwd+bwd iteration through BOTH attention stacks on the Pallas
    kernel path (interpret mode on CPU) — proves the custom-VJP kernels stay
    jit-compatible end-to-end.  Exits non-zero on NaN/Inf."""
    os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import (BSAConfig, bsa_attention, bsa_init,
                            nsa_causal_attention, nsa_init)

    B, N, Hq, Hkv, D, dm = 1, 128, 4, 2, 32, 64
    cfg = BSAConfig(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
                    top_k=2, group_size=8, backend="pallas")
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, N, Hq, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    mask = jnp.ones((B, N), bool).at[:, -16:].set(False)

    runs = [
        ("bsa", bsa_init, lambda p: bsa_attention(p, q, k, v, cfg=cfg, mask=mask)),
        ("nsa_causal", nsa_init, lambda p: nsa_causal_attention(p, q, k, v, cfg=cfg)),
    ]
    ok = True
    for name, init, apply in runs:
        params = init(ks[3], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D, d_model=dm)
        step = jax.jit(jax.value_and_grad(lambda p: jnp.sum(apply(p) ** 2)))
        t0 = time.perf_counter()
        loss, grads = step(params)
        jax.block_until_ready((loss, grads))
        dt = time.perf_counter() - t0
        finite = bool(jnp.isfinite(loss)) and all(
            bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
        ok &= finite
        print(f"smoke/{name}_train_step,{dt * 1e6:.1f},"
              f"loss={float(loss):.4f};finite={finite}", flush=True)
    if not ok:
        print("FAILURES: smoke (non-finite loss/grads)")
        sys.exit(1)
    print("# smoke complete (kernel path fwd+bwd, interpret mode)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--max-n", type=int, default=4096)
    ap.add_argument("--skip", default="", help="comma list: table1,table2,fig3,appb,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny fwd+bwd kernel-path iteration (CI gate)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    skip = set(args.skip.split(","))
    failures = []

    def section(name, fn):
        if name in skip:
            return
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — report all benches
            failures.append((name, e))
            traceback.print_exc()

    from benchmarks import appb_ablation, fig3_scaling, table1_shapenet, table2_elasticity
    section("table1+3 (ShapeNet variants)", lambda: table1_shapenet.run(steps=args.steps))
    section("table2 (Elasticity)", lambda: table2_elasticity.run(steps=args.steps))
    section("fig3 (runtime scaling)", lambda: fig3_scaling.run(max_n=args.max_n))
    section("appB (block-size ablation)",
            lambda: appb_ablation.run(steps=max(args.steps // 2, 10),
                                      grid=[(4, 4), (8, 8), (32, 32)]))

    def _roof():
        from benchmarks import roofline
        cells = roofline.load_cells(Path("results/dryrun"))
        if not cells:
            print("# (no dry-run artifacts; run repro.launch.dryrun first)")
            return
        for c in cells:
            print(f"roofline/{c['arch']}/{c['shape']},"
                  f"{max(c['compute_s'], c['memory_s'], c['collective_s'])*1e6:.1f},"
                  f"dom={c['dominant']};frac={c['roofline_fraction']:.3f}")
    section("roofline (from dry-run)", _roof)

    if failures:
        print("FAILURES:", [n for n, _ in failures])
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
