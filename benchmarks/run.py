"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--quick|--full]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--max-n", type=int, default=4096)
    ap.add_argument("--skip", default="", help="comma list: table1,table2,fig3,appb,roofline")
    args = ap.parse_args()
    skip = set(args.skip.split(","))
    failures = []

    def section(name, fn):
        if name in skip:
            return
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — report all benches
            failures.append((name, e))
            traceback.print_exc()

    from benchmarks import appb_ablation, fig3_scaling, table1_shapenet, table2_elasticity
    section("table1+3 (ShapeNet variants)", lambda: table1_shapenet.run(steps=args.steps))
    section("table2 (Elasticity)", lambda: table2_elasticity.run(steps=args.steps))
    section("fig3 (runtime scaling)", lambda: fig3_scaling.run(max_n=args.max_n))
    section("appB (block-size ablation)",
            lambda: appb_ablation.run(steps=max(args.steps // 2, 10),
                                      grid=[(4, 4), (8, 8), (32, 32)]))

    def _roof():
        from benchmarks import roofline
        from pathlib import Path
        cells = roofline.load_cells(Path("results/dryrun"))
        if not cells:
            print("# (no dry-run artifacts; run repro.launch.dryrun first)")
            return
        for c in cells:
            print(f"roofline/{c['arch']}/{c['shape']},"
                  f"{max(c['compute_s'], c['memory_s'], c['collective_s'])*1e6:.1f},"
                  f"dom={c['dominant']};frac={c['roofline_fraction']:.3f}")
    section("roofline (from dry-run)", _roof)

    if failures:
        print("FAILURES:", [n for n, _ in failures])
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
