"""Paper Fig. 3 / App. C: attention runtime vs sequence length.

One attention call (B=1, 4 heads, D=32), BSA vs Full Attention, N from 256
up (default 8192 on this CPU; --max-n 65536 reproduces the paper's axis).
The paper's claim: crossover near N≈4096, ~5× at 65536."""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, time_fn
from repro.core import BSAConfig, bsa_attention, bsa_init, full_attention


def run(max_n=8192, variants=("bsa", "full", "bsa-group-cmp")):
    key = jax.random.PRNGKey(0)
    H, D = 4, 32
    cfg = BSAConfig(ball_size=256, cmp_block=8, top_k=4, group_size=8,
                    jnp_chunk_tokens=1024)
    cfg_gc = BSAConfig(ball_size=256, cmp_block=8, top_k=4, group_size=8,
                       group_compression=True, phi="mlp", jnp_chunk_tokens=1024)
    params = bsa_init(key, cfg, n_heads=H, n_kv_heads=H, head_dim=D, d_model=128)
    params_gc = bsa_init(key, cfg_gc, n_heads=H, n_kv_heads=H, head_dim=D,
                         d_model=128)
    results = {}
    n = 256
    while n <= max_n:
        q = jax.random.normal(key, (1, n, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, n, H, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, n, H, D))
        row = {}
        if "bsa" in variants:
            f = jax.jit(lambda q, k, v: bsa_attention(params, q, k, v, cfg=cfg))
            row["bsa"] = time_fn(f, q, k, v)
        if "bsa-group-cmp" in variants:
            f = jax.jit(lambda q, k, v: bsa_attention(params_gc, q, k, v, cfg=cfg_gc))
            row["bsa-group-cmp"] = time_fn(f, q, k, v)
        if "full" in variants and n <= 32768:
            f = jax.jit(lambda q, k, v: full_attention(q, k, v))
            row["full"] = time_fn(f, q, k, v)
        for name, us in row.items():
            emit(f"fig3/{name}/n={n}", us,
                 f"speedup_vs_full={row.get('full', float('nan')) / us:.2f}")
        results[n] = row
        n *= 2
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=8192)
    args = ap.parse_args()
    res = run(max_n=args.max_n)
    ns = sorted(res)
    if "full" in res[ns[-1]] and "bsa" in res[ns[-1]]:
        print(f"# crossover check: at N={ns[-1]} BSA is "
              f"{res[ns[-1]]['full'] / res[ns[-1]]['bsa']:.2f}x faster than full")


if __name__ == "__main__":
    main()
