import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf iteration tool: lower ONE (arch × shape) cell with config/sharding
overrides and report the three roofline terms + delta vs the recorded
baseline.  Each hypothesis→change→measure cycle is one invocation.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch stablelm-1.6b \
      --shape train_4k --layout dp --chunk 1024

Overrides:
  --layout {tp,dp}     dp = no tensor parallelism; batch shards over the
                       WHOLE mesh (pod×data×model) and params go ZeRO/FSDP
                       over all axes — the right mapping for small models
  --chunk N            jnp_chunk_tokens override (0 = unchunked)
  --attn-seq           attn_shard_mode=sequence (ball-parallel attention)
  --topk N / --ell N   BSA selection/compression overrides
  --window N           local window override
  --fsdp               force FSDP params
"""

import argparse
import dataclasses
import json
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


def lower_with_overrides(arch, shape_name, *, mcfg=None, layout="tp",
                         multi_pod=False):
    """Variant of launch.dryrun.lower_cell accepting a modified mcfg/layout."""
    import jax.numpy as jnp
    from repro.distributed.params import (batch_shardings, cache_shardings,
                                          opt_shardings, param_shardings)
    from repro.distributed.sharding import axis_rules
    from repro.launch.dryrun import shape_rules
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
    from repro.models.api import model_api
    from repro.optim import adamw_init

    mcfg = mcfg or get_config(arch)
    shape = SHAPES[shape_name]
    api = model_api(mcfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules, seq_parallel = shape_rules(mcfg, shape, mesh)
    if layout == "dp":
        rules["batch"] = ("pod", "data", "model")
        rules["seq_res"] = None          # no TP ⇒ no Megatron-SP residual
        rules["heads"] = None
        rules["d_ff"] = None
        rules["vocab"] = None
        rules["experts"] = None

    B, N = shape.global_batch, shape.seq_len
    params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = param_shardings(params_struct, mesh, zero1=mcfg.fsdp or layout == "dp",
                           tp=layout == "tp")
    with mesh, axis_rules(mesh, rules):
        if shape.kind == "train":
            opt_struct = jax.eval_shape(
                lambda p: adamw_init(p, state_dtype=jnp.dtype(mcfg.opt_state_dtype)),
                params_struct)
            o_sh = opt_shardings(opt_struct, mesh, tp=layout == "tp")
            bspec = api.batch_specs(B, N)
            b_sh = batch_shardings(bspec, mesh, seq_parallel=seq_parallel,
                                   full_dp=layout == "dp")
            lowered = jax.jit(make_train_step(api), in_shardings=(p_sh, o_sh, b_sh),
                              donate_argnums=(0, 1)).lower(
                params_struct, opt_struct, bspec)
        elif shape.kind == "prefill":
            bspec = api.batch_specs(B, N)
            b_sh = batch_shardings(bspec, mesh, seq_parallel=seq_parallel,
                                   full_dp=layout == "dp")
            lowered = jax.jit(make_prefill_step(api), in_shardings=(p_sh, b_sh)).lower(
                params_struct, bspec)
        else:
            cspec = api.cache_specs(B, N)
            c_sh = cache_shardings(cspec, mesh, seq_parallel=seq_parallel)
            tok = jax.ShapeDtypeStruct((B,), jnp.int32)
            t_sh = batch_shardings(tok, mesh)
            lowered = jax.jit(make_serve_step(api), in_shardings=(p_sh, c_sh, t_sh),
                              donate_argnums=(1,)).lower(params_struct, cspec, tok)
    return lowered, mesh


def measure(lowered, mesh) -> dict:
    compiled = lowered.compile()
    hh = analyze_hlo(compiled.as_text())
    ma = compiled.memory_analysis()
    comp = hh["dot_flops_weighted"] / PEAK_FLOPS_BF16
    mem = hh["traffic_bytes_weighted"] / HBM_BW
    coll = hh["collective_wire_bytes"] / ICI_BW_PER_LINK
    peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    upcast = min(hh["bf16_upcast_bytes"], ma.temp_size_in_bytes)
    return {
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": max(("compute", comp), ("memory", mem),
                        ("collective", coll), key=lambda t: t[1])[0],
        "bound_s": max(comp, mem, coll),
        "roofline_fraction": comp / max(comp, mem, coll),
        "peak_tpu_gib": max(peak - upcast,
                            ma.argument_size_in_bytes) / 2**30,
        "collectives": {k: round(v["bytes"] / 2**20)
                        for k, v in hh["collectives"].items()},
    }


def apply_overrides(mcfg, args):
    bsa = mcfg.bsa
    kw = {}
    if args.chunk is not None:
        kw["jnp_chunk_tokens"] = args.chunk
    if args.topk:
        kw["top_k"] = args.topk
    if args.ell:
        kw["cmp_block"] = args.ell
        kw["slc_block"] = args.ell
    if args.window:
        kw["local_window"] = args.window
    if args.backend:
        kw["backend"] = args.backend
    if kw:
        bsa = dataclasses.replace(bsa, **kw)
    m = {}
    if args.attn_seq:
        m["attn_shard_mode"] = "sequence"
    if args.fsdp:
        m["fsdp"] = True
    return mcfg.scaled(bsa=bsa, **m)


def time_kernel_train_step(args) -> None:
    """§Kernel-path training: EXECUTE (not just lower) one full fwd+bwd
    train step of BSA attention on a named backend (default ``pallas``;
    ``--backend jnp|interpret|...`` swaps it with no other changes) and
    report wall time — the measurement the differentiable Pallas path
    unlocks.  On this CPU container the pallas backend runs under interpret
    mode (set REPRO_PALLAS_INTERPRET=0 on TPU hosts for compiled numbers).

    Also reports PEAK step memory (argument + temp + output − aliased, from
    the compiled step's memory analysis) — the number the kernel-native GQA
    path moves, since the rep× ``repeat_kv`` K/V blowup is gone.

    With ``--batch B > 1`` the same step is ALSO timed as B sequential
    single-sample calls (the pre-ragged-batching trainer pattern) and both
    are reported as points/sec — the batched-path speedup measurement.
    ``--ragged`` builds a HIGH-VARIANCE mixed-size batch (sizes spanning N
    down to max(N//8, ball)) and times it BOTH ways: bucket-padded dummy
    slots (per-sample masks, the classic layout) and packed-varlen (one
    concatenated axis + offsets, ``bsa_attention_varlen`` — docs/varlen.md).
    The packed numbers are the headline record; the padded ones ride along
    so the padding-waste delta is visible in the same JSON.

    ``--autotune`` enables the tile autotuner (``kernels/tuning.py``): cache
    misses are measured with timed kernel runs and persisted to the JSON
    cache ($REPRO_TUNING_CACHE, default ~/.cache/repro/tuning.json); a
    second run hits the cache and re-measures nothing.  ``--bench-json``
    writes the measured record; ``--baseline BENCH_perf_iter.json`` compares
    against a committed record and exits non-zero if throughput regressed
    more than ``--max-regression`` (CI gate).

      PYTHONPATH=src python -m benchmarks.perf_iter --kernel-step \
          --n 256 --batch 8 --heads 4 --kv-heads 2 --head-dim 32 --ragged
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_fn
    from repro.core import BSAConfig, bsa_attention, bsa_init
    from repro.core.backend import resolve_backend_name
    from repro.kernels.common import should_interpret

    B, N, Hq, Hkv, D = args.batch, args.n, args.heads, args.kv_heads, args.head_dim
    ball = min(64, N)
    if N % ball or N % 8:
        raise SystemExit(f"--n {N} must be a multiple of the ball size {ball} "
                         "(and of the group size 8)")
    backend = args.backend or "pallas"
    cfg = BSAConfig(ball_size=ball, local_window=ball,
                    cmp_block=args.ell or 8, slc_block=args.ell or 8,
                    top_k=args.topk or 4, group_size=8, backend=backend,
                    score_dtype=args.score_dtype)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, N, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, N, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, N, Hkv, D), jnp.float32)
    if args.ragged:
        # HIGH-VARIANCE mixed-size batch: sizes span N down to max(N//8,
        # ball) — the regime where dummy-padded slots waste the most FLOPs
        # and the packed-varlen layout pays off hardest (docs/varlen.md)
        lo = max(N // 8, ball)
        lens = [max(lo, N - i * (N - lo) // max(B - 1, 1)) for i in range(B)]
        mask = jnp.stack([jnp.arange(N) < n for n in lens])
        n_pts = sum(lens)
    else:
        mask = None
        n_pts = B * N
    params = bsa_init(ks[3], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                      d_model=Hq * D)

    def loss(p, q, k, v, m):
        return jnp.sum(bsa_attention(p, q, k, v, cfg=cfg, mask=m) ** 2)

    step = jax.jit(jax.value_and_grad(loss))

    def occupancy_report(fn, label):
        """One EAGER forward under the occupancy recorder (kernels/occupancy
        .py); recording is a no-op under jit tracing, so this is the only
        place live/total tile counts are concrete.  Returns {kernel:
        {live, total}} for the JSON record (None on non-kernel backends)."""
        from repro.kernels import occupancy as occ_mod
        with occ_mod.record_occupancy() as counts:
            jax.block_until_ready(fn())
        if not counts:
            print(f"# occupancy[{label}]: no kernel launches recorded "
                  f"(backend={backend})", flush=True)
            return None
        for kname, c in sorted(counts.items()):
            pct = 100.0 * c["live"] / max(c["total"], 1)
            print(f"# occupancy[{label}/{kname}]: {c['live']}/{c['total']} "
                  f"tiles live ({pct:.0f}%)", flush=True)
        return {kname: dict(c) for kname, c in counts.items()}

    occ = None
    if args.occupancy:
        occ = occupancy_report(
            lambda: bsa_attention(params, q, k, v, cfg=cfg, mask=mask),
            "padded" if args.ragged else "dense")

    def run(p, q, k, v, m):
        out, grads = step(p, q, k, v, m)
        return out

    us = time_fn(run, params, q, k, v, mask, warmup=2, iters=5)
    try:
        ma = step.lower(params, q, k, v, mask).compile().memory_analysis()
        peak_bytes = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                      + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    except Exception:
        peak_bytes = None
    resolved = resolve_backend_name(backend)     # env/context may override
    if resolved in ("jnp", "interpret"):
        mode = resolved
    else:
        mode = f"{resolved}-{'interpret' if should_interpret() else 'compiled'}"
    pps = n_pts / (us / 1e6)
    tag = "_ragged" if args.ragged else ""      # distinct trajectory entries
    emit(f"perf_iter/kernel_train_step_b{B}_n{N}{tag}", us,
         f"mode={mode};heads={Hq}/{Hkv};d={D};points_per_sec={pps:.0f};"
         f"peak_bytes={peak_bytes};score_dtype={args.score_dtype}")

    packed_stats = None
    if args.ragged:
        # the same mixed batch on the PACKED-VARLEN layout: per-sample
        # ball-padded slices concatenated on one axis, offsets instead of
        # dummy batch slots (core.bsa.bsa_attention_varlen)
        from repro.core import bsa_attention_varlen
        padded_lens = [-(-n_i // ball) * ball for n_i in lens]
        total = sum(padded_lens)
        offs_list = [0]
        for pl in padded_lens:
            offs_list.append(offs_list[-1] + pl)
        offs = jnp.asarray(offs_list, jnp.int32)
        qp = jnp.concatenate([q[i, :padded_lens[i]] for i in range(B)], axis=0)
        kp = jnp.concatenate([k[i, :padded_lens[i]] for i in range(B)], axis=0)
        vp = jnp.concatenate([v[i, :padded_lens[i]] for i in range(B)], axis=0)
        maskp = jnp.concatenate(
            [jnp.arange(padded_lens[i]) < lens[i] for i in range(B)])

        def loss_pk(p, q, k, v, m):
            return jnp.sum(bsa_attention_varlen(p, q, k, v, cfg=cfg,
                                                offsets=offs, mask=m) ** 2)

        step_pk = jax.jit(jax.value_and_grad(loss_pk))
        occ_pk = None
        if args.occupancy:
            occ_pk = occupancy_report(
                lambda: bsa_attention_varlen(params, qp, kp, vp, cfg=cfg,
                                             offsets=offs, mask=maskp),
                "packed")

        def run_pk(p, q, k, v, m):
            out, grads = step_pk(p, q, k, v, m)
            return out

        us_pk = time_fn(run_pk, params, qp, kp, vp, maskp, warmup=2, iters=5)
        try:
            ma = step_pk.lower(params, qp, kp, vp, maskp).compile() \
                        .memory_analysis()
            peak_pk = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                       + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        except Exception:
            peak_pk = None
        pps_pk = n_pts / (us_pk / 1e6)
        emit(f"perf_iter/kernel_train_step_b{B}_n{N}_packed", us_pk,
             f"mode={mode};points_per_sec={pps_pk:.0f};peak_bytes={peak_pk};"
             f"rows={total}vs{B * N}")
        print(f"# packed-varlen vs bucket-padded: {us / us_pk:.2f}x "
              f"points/sec ({pps_pk:.0f} vs {pps:.0f}); "
              f"{total} packed rows vs {B * N} padded", flush=True)
        packed_stats = {"us_per_step": round(us_pk, 1),
                        "points_per_sec": round(pps_pk, 1),
                        "peak_bytes": peak_pk,
                        "packed_rows": total, "padded_rows": B * N}
        if occ_pk is not None:
            packed_stats["occupancy"] = occ_pk

    record = {
        "shape": {"batch": B, "n": N, "heads": Hq, "kv_heads": Hkv,
                  "head_dim": D, "ragged": bool(args.ragged)},
        "mode": mode, "backend": resolved, "autotune": bool(args.autotune),
        "score_dtype": args.score_dtype,
        "us_per_step": round(us, 1), "points_per_sec": round(pps, 1),
        "peak_bytes": peak_bytes,
    }
    if occ is not None:
        record["occupancy"] = occ
    if packed_stats is not None:
        # headline = packed (what the gate tracks); padded rides along
        record["padded"] = {"us_per_step": round(us, 1),
                            "points_per_sec": round(pps, 1),
                            "peak_bytes": peak_bytes}
        record["packed"] = packed_stats
        record.update(us_per_step=packed_stats["us_per_step"],
                      points_per_sec=packed_stats["points_per_sec"],
                      peak_bytes=packed_stats["peak_bytes"])
    if args.bench_json:
        Path(args.bench_json).write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {args.bench_json}", flush=True)
    if args.baseline:
        _check_regression(record, args.baseline, args.max_regression)

    if B > 1:
        # baseline: the SAME work as B sequential single-sample steps — the
        # pre-ragged-batching trainer pattern.  A per-sample loop must also
        # sum the per-sample losses and ACCUMULATE gradients across samples
        # (the batched step gets both for free from one backward).
        qs = [q[i:i + 1] for i in range(B)]
        ks_ = [k[i:i + 1] for i in range(B)]
        vs = [v[i:i + 1] for i in range(B)]
        ms = [mask[i:i + 1] if mask is not None else None for i in range(B)]

        def run_seq(p):
            total, acc = None, None
            for i in range(B):
                li, gi = step(p, qs[i], ks_[i], vs[i], ms[i])
                total = li if total is None else total + li
                acc = gi if acc is None else jax.tree.map(jnp.add, acc, gi)
            return total, acc

        us_seq = time_fn(run_seq, params, warmup=2, iters=5)
        pps_seq = n_pts / (us_seq / 1e6)
        emit(f"perf_iter/kernel_train_step_seq{B}_n{N}{tag}", us_seq,
             f"mode={mode};points_per_sec={pps_seq:.0f}")
        print(f"# batched step vs {B} sequential steps: "
              f"{us_seq / us:.2f}x points/sec "
              f"({pps:.0f} vs {pps_seq:.0f})", flush=True)


def time_serve_benchmark(args) -> None:
    """§Serving throughput: lockstep batches vs continuous batching over the
    paged KV cache, on the SAME ragged request mix (half short, half long
    prompts — the regime where a rectangular batch wastes the most steps).

    Lockstep is the pre-paged engine: requests are grouped into rectangles
    of ``--slots``, each padded to its batch-max prompt length, and a batch
    only finishes when every slot has its ``--tokens`` generations.
    Continuous batching (``ServingEngine(paged=True).serve``) retires slots
    independently and admits queued requests mid-flight, so useful
    tokens/sec is the honest comparison: the SAME R·tokens generations
    divided by each mode's wall time.  Smoke-scale model on CPU — compare
    runs on similar hosts only.

      PYTHONPATH=src python -m benchmarks.perf_iter --serve \
          --slots 4 --requests 8 --tokens 16 --max-len 256
    """
    import time as _time

    import numpy as np

    from repro.configs import get_config
    from repro.configs.reduce import smoke_config
    from repro.models.api import model_api
    from repro.serving import ServingEngine

    mcfg = smoke_config(get_config(args.arch or "tinyllama-1.1b"))
    if args.backend:
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa,
                                                   backend=args.backend))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    B, R, NEW, S = args.slots, args.requests, args.tokens, args.max_len
    rng = np.random.default_rng(0)
    lens = np.where(np.arange(R) % 2 == 0,
                    rng.integers(16, 33, R),
                    rng.integers(S // 2, S - NEW, R))
    prompts = [rng.integers(0, mcfg.vocab_size, int(n), dtype=np.int32)
               for n in lens]
    useful = R * NEW

    def run_lockstep(eng):
        for s in range(0, R, B):
            chunk = prompts[s:s + B]
            chunk = chunk + [chunk[-1]] * (B - len(chunk))   # dummy tail slots
            rect = np.zeros((B, max(len(p) for p in chunk)), np.int32)
            for i, p in enumerate(chunk):
                rect[i, :len(p)] = p       # zero-padded: cost model only —
            eng.reset()                    # lockstep CAN'T serve ragged rows
            eng.generate(rect, NEW)

    lock = ServingEngine(api, params, batch_slots=B, max_len=S)
    run_lockstep(lock)                                       # jit warmup
    t0 = _time.perf_counter()
    run_lockstep(lock)
    t_lock = _time.perf_counter() - t0

    paged = ServingEngine(api, params, batch_slots=B, max_len=S, paged=True)
    paged.serve(prompts, max_new_tokens=NEW)                 # jit warmup
    paged.reset()
    t0 = _time.perf_counter()
    paged.serve(prompts, max_new_tokens=NEW)
    t_paged = _time.perf_counter() - t0
    steps_paged = paged.serve_steps // 2                     # two equal runs

    tps_lock = useful / t_lock
    tps_paged = useful / t_paged
    from benchmarks.common import emit
    emit(f"perf_iter/serve_lockstep_b{B}_r{R}", t_lock * 1e6 / useful,
         f"tokens_per_sec={tps_lock:.1f}")
    emit(f"perf_iter/serve_paged_b{B}_r{R}", t_paged * 1e6 / useful,
         f"tokens_per_sec={tps_paged:.1f};steps={steps_paged};"
         f"page={paged.page}")
    print(f"# continuous vs lockstep: {tps_paged / tps_lock:.2f}x useful "
          f"tokens/sec ({tps_paged:.0f} vs {tps_lock:.0f}) on "
          f"{R} requests, prompt lens {lens.min()}..{lens.max()}", flush=True)

    record = {
        "serving": True,
        "shape": {"slots": B, "requests": R, "new_tokens": NEW, "max_len": S,
                  "prompt_lens": [int(n) for n in lens]},
        "page": paged.page,
        "lockstep": {"tokens_per_sec": round(tps_lock, 1),
                     "wall_s": round(t_lock, 3)},
        "paged": {"tokens_per_sec": round(tps_paged, 1),
                  "wall_s": round(t_paged, 3), "steps": steps_paged},
        "tokens_per_sec": round(tps_paged, 1),
        "speedup_vs_lockstep": round(tps_paged / tps_lock, 2),
    }
    if args.bench_json:
        Path(args.bench_json).write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {args.bench_json}", flush=True)
    if args.baseline:
        _check_regression(record, args.baseline, args.max_regression)


def time_mesh_benchmark(args) -> None:
    """§Sharded scaling: one executed fwd+bwd BSA train step on a single
    device vs the SAME step under the ``"sharded"`` backend on an N-device
    ``make_local_mesh`` (``--mesh N`` — devices are XLA host-platform fakes
    on CPU, so this measures the shard_map partitioning overhead/benefit,
    not real multi-chip speedup; compare runs on similar hosts only).

    The recorded ``scaling_efficiency`` is the sharded/single throughput
    RATIO measured in the same invocation, so the CI gate is invariant to
    runner speed (the serving ``speedup_vs_lockstep`` pattern).  On shared-
    core fake devices the honest expectation is ≈1, not N.

      PYTHONPATH=src python -m benchmarks.perf_iter --mesh 8 \
          --n 1024 --batch 2 --heads 4 --kv-heads 2 --head-dim 32
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_fn
    from repro.core import BSAConfig, bsa_attention, bsa_init
    from repro.core.backend import use_backend
    from repro.distributed import mesh_context
    from repro.launch.mesh import make_local_mesh

    p = args.mesh
    B, N = args.batch, args.n
    Hq, Hkv, D = args.heads, args.kv_heads, args.head_dim
    ball = 64
    if N % (p * ball):
        raise SystemExit(f"--mesh {p}: --n {N} must be a multiple of "
                         f"{p} devices x ball {ball}")
    cfg = BSAConfig(ball_size=ball, local_window=ball, cmp_block=8, top_k=4,
                    group_size=8, backend=args.backend or "jnp")
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = bsa_init(ks[0], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                      d_model=Hq * D)
    q = jax.random.normal(ks[1], (B, N, Hq, D), jnp.float32)
    k = jax.random.normal(ks[2], (B, N, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[3], (B, N, Hkv, D), jnp.float32)

    def loss(p_, q, k, v):
        return (bsa_attention(p_, q, k, v, cfg=cfg) ** 2).sum() / N

    n_pts = B * N
    step_1 = jax.jit(jax.value_and_grad(loss))        # traced single-device
    us_1 = time_fn(lambda *a: jax.block_until_ready(step_1(*a)),
                   params, q, k, v, warmup=2, iters=5)
    mesh = make_local_mesh(p)
    with mesh_context(mesh), use_backend("sharded"):
        step_p = jax.jit(jax.value_and_grad(loss))    # traced sharded
        us_p = time_fn(lambda *a: jax.block_until_ready(step_p(*a)),
                       params, q, k, v, warmup=2, iters=5)
    pps_1, pps_p = n_pts / (us_1 / 1e6), n_pts / (us_p / 1e6)
    eff = pps_p / pps_1
    emit(f"perf_iter/mesh{p}_train_step_b{B}_n{N}", us_p,
         f"points_per_sec={pps_p:.0f};single_dev={pps_1:.0f};"
         f"scaling_efficiency={eff:.2f}")
    print(f"# sharded x{p} vs single device: {eff:.2f}x points/sec "
          f"({pps_p:.0f} vs {pps_1:.0f})", flush=True)

    record = {
        "mesh": p,
        "shape": {"batch": B, "n": N, "heads": Hq, "kv_heads": Hkv,
                  "head_dim": D},
        "backend_inner": args.backend or "jnp",
        "single": {"us_per_step": round(us_1, 1),
                   "points_per_sec": round(pps_1, 1)},
        "sharded": {"us_per_step": round(us_p, 1),
                    "points_per_sec": round(pps_p, 1)},
        "points_per_sec": round(pps_p, 1),
        "scaling_efficiency": round(eff, 3),
    }
    if args.ring:
        record["ring"] = _time_ring_leg(args, mesh, p, B, N, Hq, Hkv, D)
    if args.bench_json:
        Path(args.bench_json).write_text(json.dumps(record, indent=1) + "\n")
        print(f"# wrote {args.bench_json}", flush=True)
    if args.baseline:
        _check_regression(record, args.baseline, args.max_regression)


def _time_ring_leg(args, mesh, p, B, N, Hq, Hkv, D) -> dict:
    """§Ring context parallelism: one executed fwd+bwd NSA-causal step —
    token-causal ring flash + ring selection, the two ops that used to fall
    back — single device vs sharded, in the same invocation.  Alongside the
    runner-speed-invariant scaling ratio the record stamps the ANALYTIC
    invariants the ring buys: per-shard selection K/V bytes (1/p of the old
    replicated strategy), the causal hop skip rate from the static
    ``ring_hop_live`` table (~half of p² shard-hops), and the v5e ICI
    roofline of one rotation cycle."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_fn
    from repro.core import BSAConfig
    from repro.core.backend import use_backend
    from repro.core.nsa_causal import nsa_causal_attention, nsa_init
    from repro.distributed import mesh_context
    from repro.kernels.occupancy import ring_hop_live
    from repro.launch.mesh import ring_roofline_us

    cfg = BSAConfig(ball_size=min(64, N), local_window=min(64, N),
                    cmp_block=8, slc_block=8, top_k=4, group_size=8,
                    backend=args.backend or "jnp")
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    params = nsa_init(ks[0], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                      d_model=Hq * D)
    q = jax.random.normal(ks[1], (B, N, Hq, D), jnp.float32)
    k = jax.random.normal(ks[2], (B, N, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[3], (B, N, Hkv, D), jnp.float32)

    def loss(p_, q, k, v):
        return (nsa_causal_attention(p_, q, k, v, cfg=cfg) ** 2).sum() / N

    step_1 = jax.jit(jax.value_and_grad(loss))
    us_1 = time_fn(lambda *a: jax.block_until_ready(step_1(*a)),
                   params, q, k, v, warmup=2, iters=5)
    with mesh_context(mesh), use_backend("sharded"):
        step_p = jax.jit(jax.value_and_grad(loss))
        us_p = time_fn(lambda *a: jax.block_until_ready(step_p(*a)),
                       params, q, k, v, warmup=2, iters=5)
    n_pts = B * N
    pps_1, pps_p = n_pts / (us_1 / 1e6), n_pts / (us_p / 1e6)
    eff = pps_p / pps_1

    live = ring_hop_live(p, N // p, causal=True)
    hops_live, hops_total = int(live.sum()), p * p
    # per-shard selection K/V residency: the old strategy all-gathered the
    # full fp32 K+V; the ring keeps the local slab and rotates it
    repl_bytes = 2 * B * N * Hkv * D * 4
    ring_bytes = repl_bytes // p
    emit(f"perf_iter/ring{p}_nsa_step_b{B}_n{N}", us_p,
         f"points_per_sec={pps_p:.0f};single_dev={pps_1:.0f};"
         f"scaling_efficiency={eff:.2f};hops={hops_live}/{hops_total};"
         f"kv_bytes_per_shard={ring_bytes}")
    print(f"# ring x{p} vs single device: {eff:.2f}x points/sec "
          f"({pps_p:.0f} vs {pps_1:.0f}); causal hops {hops_live}/{hops_total}"
          f" ({100 * hops_live // hops_total}%); selection K/V/shard "
          f"{ring_bytes} vs {repl_bytes} replicated (1/{p})", flush=True)
    return {
        "single": {"us_per_step": round(us_1, 1),
                   "points_per_sec": round(pps_1, 1)},
        "sharded": {"us_per_step": round(us_p, 1),
                    "points_per_sec": round(pps_p, 1)},
        "scaling_efficiency": round(eff, 3),
        "causal_hops": {"live": hops_live, "total": hops_total,
                        "skip_pct": round(100 * (1 - hops_live / hops_total))},
        "selection_kv_bytes_per_shard": {"ring": ring_bytes,
                                         "replicated": repl_bytes,
                                         "ratio": round(ring_bytes / repl_bytes, 4)},
        "rotation_roofline_us_v5e": round(
            ring_roofline_us(ring_bytes, p - 1), 2),
    }


def _check_regression(record: dict, baseline_path: str, max_regression: float):
    """CI gate: fail when throughput regressed > max_regression vs the
    committed baseline record.  Ragged records compare against the
    baseline's ``ragged_varlen.packed`` entry, bf16 ones against
    ``mixed_precision.after`` (fp32 and bf16 wall times are not comparable
    on CPU, which emulates bf16); dense fp32 records read the ``after``
    entry (or a flat record)."""
    p = Path(baseline_path)
    if not p.exists():
        print(f"# baseline {baseline_path} missing — regression gate skipped",
              flush=True)
        return
    base = json.loads(p.read_text())
    if record.get("mesh"):
        # gate on the sharded/single-device RATIO measured in one
        # invocation — invariant to runner speed like the serving gate
        base_eff = base.get("sharded_mesh", {}).get("scaling_efficiency")
        if not base_eff:
            print("# baseline has no sharded_mesh.scaling_efficiency — "
                  "regression gate skipped", flush=True)
            return
        eff = record["scaling_efficiency"]
        ratio = eff / base_eff
        print(f"# scaling efficiency vs baseline: {ratio:.2f}x "
              f"({eff:.2f} vs {base_eff:.2f} sharded/single)", flush=True)
        if ratio < 1.0 - max_regression:
            raise SystemExit(
                f"sharded scaling regression: {eff:.2f} sharded/single is "
                f"{(1 - ratio) * 100:.0f}% below baseline {base_eff:.2f} "
                f"(allowed: {max_regression * 100:.0f}%)")
        ring_eff = record.get("ring", {}).get("scaling_efficiency")
        base_ring = base.get("sharded_ring", {}).get("scaling_efficiency")
        if ring_eff and base_ring:
            ratio = ring_eff / base_ring
            print(f"# ring scaling efficiency vs baseline: {ratio:.2f}x "
                  f"({ring_eff:.2f} vs {base_ring:.2f} sharded/single)",
                  flush=True)
            if ratio < 1.0 - max_regression:
                raise SystemExit(
                    f"ring scaling regression: {ring_eff:.2f} sharded/single "
                    f"is {(1 - ratio) * 100:.0f}% below baseline "
                    f"{base_ring:.2f} (allowed: {max_regression * 100:.0f}%)")
        elif ring_eff:
            print("# baseline has no sharded_ring.scaling_efficiency — "
                  "ring gate skipped", flush=True)
        return
    if record.get("serving"):
        # gate on the paged/lockstep RATIO, not absolute tok/s: both modes
        # run on the same host in the same invocation, so the ratio is
        # invariant to runner speed while absolute wall-clock is not
        base_spd = base.get("serving_paged", {}).get("after", {}) \
                       .get("speedup_vs_lockstep")
        if not base_spd:
            print("# baseline has no serving_paged.after.speedup_vs_lockstep"
                  " — regression gate skipped", flush=True)
            return
        spd = record["speedup_vs_lockstep"]
        ratio = spd / base_spd
        print(f"# serving speedup vs baseline: {ratio:.2f}x "
              f"({spd:.2f}x vs {base_spd:.2f}x over lockstep)", flush=True)
        if ratio < 1.0 - max_regression:
            raise SystemExit(
                f"serving throughput regression: {spd:.2f}x over lockstep is "
                f"{(1 - ratio) * 100:.0f}% below baseline {base_spd:.2f}x "
                f"(allowed: {max_regression * 100:.0f}%)")
        return
    if record["shape"].get("ragged") and "ragged_varlen" in base:
        base = base["ragged_varlen"].get("packed", {})
    elif (record.get("score_dtype") == "bfloat16"
          and "mixed_precision" in base):
        base = base["mixed_precision"].get("after", {})
    else:
        base = base.get("after", base)           # before/after trajectory file
    base_pps = base.get("points_per_sec")
    if not base_pps:
        print("# baseline has no points_per_sec — regression gate skipped",
              flush=True)
        return
    ratio = record["points_per_sec"] / base_pps
    print(f"# throughput vs baseline: {ratio:.2f}x "
          f"({record['points_per_sec']:.0f} vs {base_pps:.0f} points/sec)",
          flush=True)
    if ratio < 1.0 - max_regression:
        raise SystemExit(
            f"throughput regression: {record['points_per_sec']:.0f} points/sec "
            f"is {(1 - ratio) * 100:.0f}% below baseline {base_pps:.0f} "
            f"(allowed: {max_regression * 100:.0f}%)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"])
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--topk", type=int, default=0)
    ap.add_argument("--ell", type=int, default=0)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--attn-seq", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="attention backend: jnp | pallas | interpret | auto "
                         "| any registered plug-in (kernel-step default: pallas)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--kernel-step", action="store_true",
                    help="time one executed fwd+bwd BSA step on the kernel path "
                         "(--batch B>1 also times B sequential single-sample "
                         "steps for the batched-path comparison)")
    ap.add_argument("--ragged", action="store_true",
                    help="kernel-step: high-variance mixed-size batch, timed "
                         "both bucket-padded and packed-varlen (offsets)")
    ap.add_argument("--occupancy", action="store_true",
                    help="kernel-step: run one eager forward under the tile-"
                         "occupancy recorder and report live/total tile "
                         "counts per kernel (kernels/occupancy.py); counts "
                         "are included in the --bench-json record")
    ap.add_argument("--score-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="kernel-step: BSAConfig.score_dtype — bfloat16 runs "
                         "the kernel precision contract (bf16 QK^T/PV "
                         "operands, fp32 accumulation)")
    ap.add_argument("--autotune", action="store_true",
                    help="enable the tile autotuner (kernels/tuning.py): "
                         "measure candidate (tq, tk) grids on cache miss and "
                         "persist to $REPRO_TUNING_CACHE "
                         "(~/.cache/repro/tuning.json); second run hits cache")
    ap.add_argument("--bench-json", default=None,
                    help="kernel-step: write the measured record "
                         "(points/sec, peak bytes) to this JSON file")
    ap.add_argument("--baseline", default=None,
                    help="kernel-step: committed baseline JSON to gate "
                         "against (BENCH_perf_iter.json)")
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="allowed fractional throughput drop vs --baseline "
                         "before failing (default 0.2)")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=32)
    ap.add_argument("--mesh", type=int, default=0,
                    help="time one fwd+bwd BSA step single-device vs the "
                         "'sharded' backend on an N-device local mesh; "
                         "--bench-json/--baseline gate the runner-speed-"
                         "invariant scaling_efficiency ratio")
    ap.add_argument("--ring", action="store_true",
                    help="with --mesh: also time an NSA-causal step (token-"
                         "causal ring flash + ring selection) and record the "
                         "sharded_ring entry — scaling efficiency, causal "
                         "hop skip rate, per-shard selection K/V bytes")
    ap.add_argument("--serve", action="store_true",
                    help="time lockstep batches vs paged continuous batching "
                         "on a ragged request mix (useful tokens/sec; "
                         "--bench-json/--baseline gate the paged number)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    if args.autotune:
        # must be set before the first attention trace resolves tiles
        os.environ["REPRO_AUTOTUNE"] = "1"
    if args.serve:
        time_serve_benchmark(args)
        return
    if args.mesh:
        time_mesh_benchmark(args)
        return
    if args.kernel_step:
        time_kernel_train_step(args)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (unless --kernel-step)")

    mcfg = apply_overrides(get_config(args.arch), args)
    lowered, mesh = lower_with_overrides(args.arch, args.shape, mcfg=mcfg,
                                         layout=args.layout)
    m = measure(lowered, mesh)
    base_p = Path(f"results/dryrun/{args.arch}__{args.shape}__pod1.json")
    base = json.loads(base_p.read_text()) if base_p.exists() else None
    print(json.dumps({"tag": args.tag or "iter", **m}, indent=1))
    if base and base.get("ok"):
        b_comp = base["flops_per_device"] / PEAK_FLOPS_BF16
        b_mem = base["traffic_bytes_per_device"] / HBM_BW
        b_coll = base["collective_wire_bytes"] / ICI_BW_PER_LINK
        b_bound = max(b_comp, b_mem, b_coll)
        print(f"baseline bound {b_bound*1e3:.1f} ms → now {m['bound_s']*1e3:.1f} ms "
              f"({b_bound/max(m['bound_s'],1e-12):.2f}x better); "
              f"roofline frac {b_comp/max(b_bound,1e-12):.3f} → {m['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
