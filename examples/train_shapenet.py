"""End-to-end driver: train the paper's 18-block BSA model on the (synthetic)
ShapeNet-Car airflow-pressure task — checkpointing, watchdog and all.

    PYTHONPATH=src python examples/train_shapenet.py --steps 300 --arch shapenet-bsa

Any Table-3 variant works: shapenet-bsa | shapenet-bsa-no-group |
shapenet-bsa-group-cmp | shapenet-full | shapenet-erwin.
"""

import argparse


from repro.configs import get_config
from repro.data import ShapeNetCarDataset
from repro.models.api import model_api
from repro.runtime import Trainer, TrainerConfig


def evaluate(api, params, ds, n_batches=8, batch_size=8):
    mse, n = 0.0, 0
    import jax, jax.numpy as jnp
    fwd = jax.jit(api.forward)
    for i, batch in enumerate(ds.batches(batch_size, shuffle=False, epochs=1)):
        if i >= n_batches:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        pred = fwd(params, batch)
        m = batch["mask"][..., None]
        mse += float((((pred - batch["target"]) ** 2) * m).sum() / m.sum())
        n += 1
    return mse / max(n, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="shapenet-bsa")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--layers", type=int, default=0, help="override (0=config)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--use-kernels", action="store_true",
                    help="train through the fused Pallas kernels (the custom-VJP "
                         "backward path; interpret mode on CPU, compiled on TPU)")
    args = ap.parse_args()

    mcfg = get_config(args.arch)
    if args.layers:
        mcfg = mcfg.scaled(n_layers=args.layers)
    if args.use_kernels:
        import dataclasses
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, use_kernels=True))
    api = model_api(mcfg)
    train_ds = ShapeNetCarDataset("train")
    test_ds = ShapeNetCarDataset("test")

    cfg = TrainerConfig(base_lr=1e-3, weight_decay=0.01,       # paper App. A
                        total_steps=args.steps, warmup_steps=min(50, args.steps // 10),
                        ckpt_dir=args.ckpt, log_every=20)
    tr = Trainer(api, cfg)
    params, _ = tr.fit(train_ds.batches(args.batch, seed=0), steps=args.steps)
    mse = evaluate(api, params, test_ds)
    print(f"\n[{args.arch}] test MSE after {args.steps} steps: {mse:.4f}")
    print(f"wall time {tr.wall_time:.1f}s, stragglers: {len(tr.watchdog.straggler_events)}")


if __name__ == "__main__":
    main()
