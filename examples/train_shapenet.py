"""End-to-end driver: train the paper's 18-block BSA model on the (synthetic)
ShapeNet-Car airflow-pressure task — checkpointing, watchdog and all.

    PYTHONPATH=src python examples/train_shapenet.py --steps 300 --arch shapenet-bsa

Any Table-3 variant works: shapenet-bsa | shapenet-bsa-no-group |
shapenet-bsa-group-cmp | shapenet-full | shapenet-erwin.

Variable-size geometries: ``--var-points LO HI`` draws every car's point
count from [LO, HI].  The dataset packs the ragged samples into one padded
batch with per-sample masks (pad_to frozen at the range maximum), so the
whole mixed-size batch still runs as ONE jitted train step — no per-sample
Python loop, no shape-churn recompilation.
"""

import argparse


from repro.configs import get_config
from repro.data import ShapeNetCarDataset
from repro.models.api import model_api
from repro.runtime import Trainer, TrainerConfig


def evaluate(api, params, ds, n_batches=8, batch_size=8, pad_to=None):
    mse, n = 0.0, 0
    import jax, jax.numpy as jnp
    fwd = jax.jit(api.forward)
    for i, batch in enumerate(ds.batches(batch_size, shuffle=False, epochs=1,
                                         pad_to=pad_to)):
        if i >= n_batches:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        pred = fwd(params, batch)
        m = batch["mask"][..., None]
        mse += float((((pred - batch["target"]) ** 2) * m).sum() / m.sum())
        n += 1
    return mse / max(n, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="shapenet-bsa")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--layers", type=int, default=0, help="override (0=config)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--backend", default=None,
                    help="attention backend: jnp | pallas | interpret | auto | "
                         "any registered plug-in (default: config; 'pallas' "
                         "trains through the fused custom-VJP kernels — "
                         "interpret mode on CPU, compiled on TPU)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="DEPRECATED: same as --backend pallas")
    ap.add_argument("--var-points", type=int, nargs=2, metavar=("LO", "HI"),
                    default=None,
                    help="ragged geometries: per-sample point counts drawn from "
                         "[LO, HI]; batches are packed + masked (batched path)")
    args = ap.parse_args()

    mcfg = get_config(args.arch)
    if args.layers:
        mcfg = mcfg.scaled(n_layers=args.layers)
    backend = args.backend
    if args.use_kernels:
        import warnings
        warnings.warn("--use-kernels is deprecated; use --backend pallas",
                      DeprecationWarning)
        backend = backend or "pallas"
    if backend:
        import dataclasses
        mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, backend=backend))
    api = model_api(mcfg)
    nrange = tuple(args.var_points) if args.var_points else None
    train_ds = ShapeNetCarDataset("train", n_points_range=nrange)
    test_ds = ShapeNetCarDataset("test", n_points_range=nrange)
    # freeze the packed length so every mixed-size batch hits ONE compiled step
    pad_to = train_ds.max_padded_len if nrange else None

    cfg = TrainerConfig(base_lr=1e-3, weight_decay=0.01,       # paper App. A
                        total_steps=args.steps, warmup_steps=min(50, args.steps // 10),
                        ckpt_dir=args.ckpt, log_every=20)
    tr = Trainer(api, cfg)
    params, _ = tr.fit(train_ds.batches(args.batch, seed=0, pad_to=pad_to),
                       steps=args.steps)
    mse = evaluate(api, params, test_ds, pad_to=pad_to)
    print(f"\n[{args.arch}] test MSE after {args.steps} steps: {mse:.4f}")
    print(f"wall time {tr.wall_time:.1f}s, stragglers: {len(tr.watchdog.straggler_events)}")


if __name__ == "__main__":
    main()
