"""Serving demo: batched decode of a (reduced) BSA LM through the engine —
prefill by decode-replay, greedy generation, tokens/s report.

    PYTHONPATH=src python examples/serve_lm.py --arch tinyllama-1.1b --tokens 32
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.reduce import smoke_config
from repro.models.api import model_api
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    mcfg = smoke_config(get_config(args.arch))   # reduced config fits CPU
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))

    eng = ServingEngine(api, params, batch_slots=args.slots, max_len=args.max_len,
                        temperature=args.temperature)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mcfg.vocab_size, (args.slots, args.prompt_len),
                           dtype=np.int32)
    out = eng.generate(prompts, args.tokens)
    print("generated:", out.shape)
    print("first slot:", out[0].tolist())
    print(f"decode throughput: {eng.tokens_per_second:.1f} tok/s "
          f"({args.slots} slots)")


if __name__ == "__main__":
    main()
