"""Quickstart: Ball Sparse Attention on a random point cloud, a packed batch
of RAGGED clouds, and the packed-varlen layout — the snippets the README/docs
are built around (CI executes this file as the docs-freshness gate).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BSAConfig,
    bsa_attention,
    bsa_attention_varlen,
    bsa_init,
    pack_varlen,
    unpack_varlen,
    use_backend,
)
from repro.core.balltree import build_balltree_permutation, ragged_ball_order, unpack_ragged

# 1. a point cloud (unordered!) and its features
rng = np.random.default_rng(0)
N, d_feat = 2048, 64
points = rng.standard_normal((N, 3)).astype(np.float32)
feats = rng.standard_normal((N, d_feat)).astype(np.float32)

# 2. impose regularity: ball-tree order — balls become contiguous chunks
cfg = BSAConfig(ball_size=256, cmp_block=8, top_k=4, group_size=8)
perm = build_balltree_permutation(points, cfg.ball_size)
feats = feats[perm][None]                       # (1, N, d)

# 3. q/k/v projections (here: random) + BSA
key = jax.random.PRNGKey(0)
H, D = 4, 16
params = bsa_init(key, cfg, n_heads=H, n_kv_heads=H, head_dim=D, d_model=d_feat)
wq, wk, wv = (jax.random.normal(k, (d_feat, H * D)) * 0.1
              for k in jax.random.split(key, 3))
x = jnp.asarray(feats)
q = (x @ wq).reshape(1, N, H, D)
k = (x @ wk).reshape(1, N, H, D)
v = (x @ wv).reshape(1, N, H, D)

out, aux = bsa_attention(params, q, k, v, cfg=cfg, return_aux=True)
print("BSA output:", out.shape)
print("branches:", {b: tuple(aux[b].shape) for b in ("ball", "cmp", "slc")})
print("selected blocks for group 0, head 0:", np.asarray(aux["indices"])[0, 0, 0])
print("gates (σ(γ)):", {b: float(g.mean()) for b, g in aux["gates"].items()})

# cost vs full attention (token-pair count)
pairs_full = N * N
pairs_bsa = N * cfg.ball_size + N * (N // cfg.cmp_block) // 1 + N * cfg.top_k * cfg.slc_block
print(f"attended pairs: full {pairs_full:.2e}  bsa {pairs_bsa:.2e} "
      f"({pairs_full / pairs_bsa:.1f}x sparser)")

# 4. RAGGED batching: three clouds of different sizes → ONE packed batch.
#    Each cloud gets its own ball tree; padding is masked keys (logit space),
#    so the batched result equals running every cloud alone.
sizes = (1500, 2048, 900)
clouds = [rng.standard_normal((n, 3)).astype(np.float32) for n in sizes]
cfeats = [rng.standard_normal((n, d_feat)).astype(np.float32) for n in sizes]
_, fts, mask, perms = ragged_ball_order(clouds, cfeats, cfg.ball_size)
B, L = mask.shape
x = jnp.asarray(fts)
qb = (x @ wq).reshape(B, L, H, D)
kb = (x @ wk).reshape(B, L, H, D)
vb = (x @ wv).reshape(B, L, H, D)
out_b = bsa_attention(params, qb, kb, vb, cfg=cfg, mask=jnp.asarray(mask))
per_cloud = unpack_ragged(np.asarray(out_b), mask)   # → one (n_i, H, D) per cloud
print("ragged batch:", {f"cloud{i}": o.shape for i, o in enumerate(per_cloud)},
      f"packed as {tuple(out_b.shape)}")
# sanity: the packed batch reproduces the single-cloud path bit-for-bit-ish.
# Cloud 0 is the interesting one: 1500 real rows + 548 masked padding rows,
# so this equality holds only if key masking actually works.
solo = bsa_attention(params, qb[0:1], kb[0:1], vb[0:1], cfg=cfg,
                     mask=jnp.asarray(mask[0:1]))
assert np.allclose(np.asarray(out_b[0]), np.asarray(solo[0]), atol=1e-5)
print("batched == per-sample (padded cloud): OK")

# 5. NAMED BACKENDS: the same call on a different execution engine.  The
#    default cfg.backend="auto" picks the Pallas kernels on TPU and the jnp
#    reference elsewhere; `with use_backend(...)` forces one for a scope
#    (REPRO_ATTENTION_BACKEND=... does the same process-wide, e.g. in CI).
qs, ks_, vs = q[:, :512], k[:, :512], v[:, :512]    # small slice — interpret
out_ref = bsa_attention(params, qs, ks_, vs, cfg=cfg)        # mode is slow
with use_backend("interpret"):      # Pallas kernel bodies, executed as Python
    out_int = bsa_attention(params, qs, ks_, vs, cfg=cfg)
assert np.allclose(np.asarray(out_ref), np.asarray(out_int), atol=1e-3)
print("backend swap jnp/auto ↔ interpret: same result, zero call-site changes")

# 6. PACKED-VARLEN: the same ragged clouds with NO dummy batch slots — all
#    clouds concatenated on ONE axis, per-sample boundaries in an `offsets`
#    array (every entry a ball multiple), so compute scales with the SUM of
#    cloud sizes instead of B x max(n_i).  See docs/varlen.md.
ordered = [fts[i][mask[i]] for i in range(B)]        # per-cloud, ball order
# pad_to freezes the packed length at the tight total (per-cloud ball
# multiples); without it the total is rounded to a geometric bucket so
# repeated calls share jit shapes.
tight = sum(-(-len(o) // cfg.ball_size) * cfg.ball_size for o in ordered)
packed, offsets, maskv = pack_varlen(ordered, cfg.ball_size, pad_to=tight)
T = packed.shape[0]
xv = jnp.asarray(packed)
qv = (xv @ wq).reshape(T, H, D)
kv_ = (xv @ wk).reshape(T, H, D)
vv = (xv @ wv).reshape(T, H, D)
out_vl = bsa_attention_varlen(params, qv, kv_, vv, cfg=cfg,
                              offsets=jnp.asarray(offsets),
                              mask=jnp.asarray(maskv))
per_cloud_vl = unpack_varlen(np.asarray(out_vl), offsets, maskv)
print(f"packed-varlen: {T} rows vs {B * L} bucket-padded "
      f"(offsets {offsets.tolist()})")
for got, want in zip(per_cloud_vl, per_cloud):
    assert np.allclose(got, want, atol=1e-4)
print("packed-varlen == bucket-padded, per cloud: OK")

# 7. MULTI-DEVICE: the "sharded" backend shard_maps the same call over a
#    mesh — balls are data-parallel, the small compressed K/V replicates.
#    Still zero call-site changes; the mesh binds at trace time like any
#    backend choice.  See docs/distributed.md.  (Run this file under
#    XLA_FLAGS=--xla_force_host_platform_device_count=2 to fake devices.)
from repro.distributed import mesh_context
from repro.launch.mesh import make_local_mesh

n_dev = min(2, len(jax.devices()))   # 512-token slice splits 2 ways cleanly
with mesh_context(make_local_mesh(n_dev)), use_backend("sharded"):
    out_sh = bsa_attention(params, qs, ks_, vs, cfg=cfg)
assert np.allclose(np.asarray(out_ref), np.asarray(out_sh), atol=1e-4)
print(f"sharded over {n_dev} device(s) == single-device: OK")
