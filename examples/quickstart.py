"""Quickstart: Ball Sparse Attention on a random point cloud in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSAConfig, bsa_attention, bsa_init
from repro.core.balltree import build_balltree_permutation

# 1. a point cloud (unordered!) and its features
rng = np.random.default_rng(0)
N, d_feat = 2048, 64
points = rng.standard_normal((N, 3)).astype(np.float32)
feats = rng.standard_normal((N, d_feat)).astype(np.float32)

# 2. impose regularity: ball-tree order — balls become contiguous chunks
cfg = BSAConfig(ball_size=256, cmp_block=8, top_k=4, group_size=8)
perm = build_balltree_permutation(points, cfg.ball_size)
feats = feats[perm][None]                       # (1, N, d)

# 3. q/k/v projections (here: random) + BSA
key = jax.random.PRNGKey(0)
H, D = 4, 16
params = bsa_init(key, cfg, n_heads=H, n_kv_heads=H, head_dim=D, d_model=d_feat)
wq, wk, wv = (jax.random.normal(k, (d_feat, H * D)) * 0.1
              for k in jax.random.split(key, 3))
x = jnp.asarray(feats)
q = (x @ wq).reshape(1, N, H, D)
k = (x @ wk).reshape(1, N, H, D)
v = (x @ wv).reshape(1, N, H, D)

out, aux = bsa_attention(params, q, k, v, cfg=cfg, return_aux=True)
print("BSA output:", out.shape)
print("branches:", {b: tuple(aux[b].shape) for b in ("ball", "cmp", "slc")})
print("selected blocks for group 0, head 0:", np.asarray(aux["indices"])[0, 0, 0])
print("gates (σ(γ)):", {b: float(g.mean()) for b, g in aux["gates"].items()})

# cost vs full attention (token-pair count)
pairs_full = N * N
pairs_bsa = N * cfg.ball_size + N * (N // cfg.cmp_block) // 1 + N * cfg.top_k * cfg.slc_block
print(f"attended pairs: full {pairs_full:.2e}  bsa {pairs_bsa:.2e} "
      f"({pairs_full / pairs_bsa:.1f}x sparser)")
