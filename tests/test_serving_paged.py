"""Continuous-batching correctness: the paged decode path against oracles.

Three oracle layers, strongest first (docs/serving.md):

* ATTENTION ORACLE — ``nsa_causal_decode_paged`` over a shuffled block
  table, with slots admitted in staggered waves at ragged lengths, must
  match the full-recompute train path ``nsa_causal_attention`` at every
  position, on every CI backend (jnp / pallas / interpret).
* ENGINE ORACLE — ``ServingEngine(paged=True).serve`` over mixed-length
  requests (≥3 admission waves) must emit exactly the tokens the proven
  lockstep engine generates per prompt — prefix reuse, copy-on-write and
  windowed scheduling included.
* HOST INVARIANTS — allocator/prefix-tree unit checks here; the randomized
  property suite lives in tests/test_paged_properties.py (hypothesis).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BSAConfig,
    init_paged_decode_cache,
    nsa_causal_attention,
    nsa_causal_decode,
    nsa_causal_decode_paged,
    nsa_init,
)
from repro.core.backend import use_backend
from repro.serving.paged_cache import BlockAllocator, PagedKVCache, PrefixCache

KEY = jax.random.PRNGKey(3)
BACKENDS = ["jnp", "pallas", "interpret"]


def _cfg(**kw):
    # group_size=0 + query_cmp_selection=False is the config whose decode
    # path is EXACT vs train (grouped selection is an approximation that
    # legitimately diverges once top-k starts discriminating)
    base = dict(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
                top_k=2, group_size=0, query_cmp_selection=False)
    base.update(kw)
    return BSAConfig(**base)


# ---------------------------------------------------------------------------
# attention-level decode oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_paged_decode_matches_train_oracle_staggered(backend):
    """Shuffled block table + 3 staggered admission waves + ragged lengths
    == full-recompute train attention, per position, per slot."""
    cfg = _cfg(backend=backend)
    B, Hq, Hkv, D = 3, 4, 2, 16
    page, n_pages, num_blocks = 32, 4, 12
    lens = [96, 64, 33]                    # ragged; max fits n_pages * page
    starts = [0, 17, 41]                   # three admission waves
    N_pad = 128                            # w-aligned oracle length
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, N_pad, Hq, D))
    k = jax.random.normal(ks[1], (B, N_pad, Hkv, D))
    v = jax.random.normal(ks[2], (B, N_pad, Hkv, D))
    params = nsa_init(ks[3], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                      d_model=Hq * D)

    with use_backend(backend):
        # oracle: full causal recompute of each slot's own sequence; causality
        # makes positions < len independent of the aligned tail padding
        ref = nsa_causal_attention(params, q, k, v, cfg=cfg)

        cache = init_paged_decode_cache(num_blocks, page, Hkv, D, cfg,
                                        dtype=jnp.float32)
        # shuffled block assignment: slot pages deliberately non-contiguous
        rng = np.random.default_rng(0)
        blocks = rng.permutation(num_blocks)
        table = np.full((B, n_pages), num_blocks, np.int32)    # all trash
        lengths = np.zeros(B, np.int32)
        step = jax.jit(lambda p, a, b, c, cc, tt, ll: nsa_causal_decode_paged(
            p, a, b, c, cc, tt, ll, cfg=cfg, page=page))
        next_blk = 0
        T = max(starts[s] + lens[s] for s in range(B))
        for t in range(T):
            for s in range(B):             # staggered admission + paging
                pos = t - starts[s]
                if 0 <= pos < lens[s] and pos % page == 0:
                    table[s, pos // page] = blocks[next_blk]
                    next_blk += 1
            active = [s for s in range(B)
                      if 0 <= t - starts[s] < lens[s]]
            pos = np.array([max(t - starts[s], 0) for s in range(B)], np.int32)
            pos = np.minimum(pos, np.array(lens) - 1).astype(np.int32)
            idx = jnp.asarray(pos)[:, None, None, None]
            q1 = jnp.take_along_axis(q, idx, axis=1)
            k1 = jnp.take_along_axis(k, idx, axis=1)
            v1 = jnp.take_along_axis(v, idx, axis=1)
            lengths_t = np.where([s in active for s in range(B)], pos, 0)
            out, cache = step(params, q1, k1, v1, cache,
                              jnp.asarray(table.copy()),
                              jnp.asarray(lengths_t.astype(np.int32)))
            for s in active:
                np.testing.assert_allclose(
                    np.asarray(out[s, 0]), np.asarray(ref[s, pos[s]]),
                    atol=2e-5,
                    err_msg=f"slot {s} pos {pos[s]} (backend {backend})")


def test_dense_decode_is_degenerate_paged_layout():
    """The lockstep wrapper (identity table, page = max_len) reproduces the
    paged core bit-for-bit — one numeric path serves both modes."""
    cfg = _cfg()
    B, N, Hq, Hkv, D = 2, 64, 4, 2, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, N, Hq, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    params = nsa_init(ks[3], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                      d_model=Hq * D)
    ref = nsa_causal_attention(params, q, k, v, cfg=cfg)
    from repro.core import init_decode_cache
    cache = init_decode_cache(B, N, Hkv, D, cfg, dtype=jnp.float32)
    step = jax.jit(lambda p, a, b, c, cc: nsa_causal_decode(p, a, b, c, cc,
                                                            cfg=cfg))
    for t in range(N):
        out, cache = step(params, q[:, t:t + 1], k[:, t:t + 1],
                          v[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, t]),
                                   atol=2e-5, err_msg=f"pos {t}")


def test_paged_cache_rejects_misaligned_page():
    with pytest.raises(ValueError):
        init_paged_decode_cache(4, 24, 2, 16, _cfg())       # 24 % w != 0


def test_paged_gather_kernel_matches_jnp():
    """The Pallas scalar-prefetch gather == fancy indexing (forced through
    the kernel even under interpret mode)."""
    from repro.kernels.ops import paged_gather
    pool = jax.random.normal(KEY, (40, 2, 16))
    rows = jnp.asarray(np.random.default_rng(0).integers(0, 40, (3, 7)),
                       jnp.int32)
    got = paged_gather(pool, rows, interpret=True, force_kernel=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(pool[rows]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# engine-level serve oracle (smoke LM)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs import get_config
    from repro.configs.reduce import smoke_config
    from repro.models.api import model_api
    mcfg = smoke_config(get_config("tinyllama-1.1b"))
    mcfg = mcfg.scaled(n_layers=1)          # one BSA layer is plenty here
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    return mcfg, api, params


def _lockstep_ref(api, params, prompt, n_tokens, max_len=128):
    from repro.serving import ServingEngine
    eng = ServingEngine(api, params, batch_slots=1, max_len=max_len)
    return eng.generate(prompt[None], n_tokens)[0]


def test_serve_matches_lockstep_three_waves(tiny_lm):
    mcfg, api, params = tiny_lm
    from repro.serving import ServingEngine
    rng = np.random.default_rng(0)
    lens = [40, 70, 20, 90, 33]            # ragged; ≥3 waves on 2 slots
    prompts = [rng.integers(0, mcfg.vocab_size, n, dtype=np.int32)
               for n in lens]
    eng = ServingEngine(api, params, batch_slots=2, max_len=128, paged=True)
    res = eng.serve(prompts, max_new_tokens=6)
    eng.kv.check()
    # every slot retired: only sealed prompt pages (prefix tree) stay live
    assert eng.kv.allocator.live_count == len(eng.kv.prefix)
    for i, p in enumerate(prompts):
        want = _lockstep_ref(api, params, p, 6)
        np.testing.assert_array_equal(res[i], want, err_msg=f"request {i}")


def test_serve_eos_retires_slot_and_admits_next(tiny_lm):
    mcfg, api, params = tiny_lm
    from repro.serving import ServingEngine
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, mcfg.vocab_size, n, dtype=np.int32)
               for n in (25, 37, 18)]
    refs = [_lockstep_ref(api, params, p, 8) for p in prompts]
    eos = int(refs[0][3])                  # force an early EOS for request 0
    eng = ServingEngine(api, params, batch_slots=1, max_len=128, paged=True)
    res = eng.serve(prompts, max_new_tokens=8, eos_id=eos)
    eng.kv.check()
    for i, (got, want) in enumerate(zip(res, refs)):
        cut = np.nonzero(want == eos)[0]
        want = want[:cut[0]] if len(cut) else want   # EOS excluded, stops
        np.testing.assert_array_equal(got, want, err_msg=f"request {i}")
    assert len(res[0]) == 3                # retired at the forced EOS


def test_serve_prefix_reuse_is_exact_and_counted(tiny_lm):
    mcfg, api, params = tiny_lm
    from repro.serving import ServingEngine
    rng = np.random.default_rng(2)
    shared = rng.integers(0, mcfg.vocab_size, 64, dtype=np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(0, mcfg.vocab_size, k,
                                            dtype=np.int32)])
               for k in (5, 11, 0)]        # k=0: fully-cached prompt (CoW)
    eng = ServingEngine(api, params, batch_slots=1, max_len=128, paged=True)
    res = eng.serve(prompts, max_new_tokens=4)
    eng.kv.check()
    assert eng.kv.blocks_reused >= 4       # 2 shared pages × 2 later requests
    assert eng.kv.cow_copies >= 1          # full-cache tail recompute
    for i, p in enumerate(prompts):
        want = _lockstep_ref(api, params, p, 4)
        np.testing.assert_array_equal(res[i], want, err_msg=f"request {i}")


def test_serve_no_prefix_cache_still_exact(tiny_lm):
    mcfg, api, params = tiny_lm
    from repro.serving import ServingEngine
    rng = np.random.default_rng(3)
    p = rng.integers(0, mcfg.vocab_size, 45, dtype=np.int32)
    eng = ServingEngine(api, params, batch_slots=1, max_len=128, paged=True,
                        prefix_cache=False)
    res = eng.serve([p, p], max_new_tokens=4)
    assert eng.kv.blocks_reused == 0
    want = _lockstep_ref(api, params, p, 4)
    np.testing.assert_array_equal(res[0], want)
    np.testing.assert_array_equal(res[1], want)


def test_generate_stops_sampling_retired_slots(tiny_lm):
    """Satellite: generate() with eos_id pads retired slots, stops counting
    them, and exits early when every slot is done."""
    mcfg, api, params = tiny_lm
    from repro.serving import ServingEngine
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, mcfg.vocab_size, (2, 24), dtype=np.int32)
    ref_eng = ServingEngine(api, params, batch_slots=2, max_len=128)
    ref = ref_eng.generate(prompts, 8)
    eos = int(ref[0, 2])                   # retire slot 0 after 2 tokens
    eng = ServingEngine(api, params, batch_slots=2, max_len=128)
    before = eng.tokens_generated
    out = eng.generate(prompts, 8, eos_id=eos, pad_id=-1)
    assert out.shape == (2, 8)
    np.testing.assert_array_equal(out[0, :2], ref[0, :2])
    assert (out[0, 2:] == -1).all()        # EOS + padding, never resampled
    row1 = out[1]
    live1 = row1[row1 != -1]
    np.testing.assert_array_equal(live1, ref[1, :len(live1)])
    counted = eng.tokens_generated - before
    assert counted < 16                    # retired slot not counted


def test_reset_threads_cache_dtype(tiny_lm):
    """Satellite: reset() keeps the constructed dtype and reset(dtype=...)
    actually switches it — in both engine modes."""
    mcfg, api, params = tiny_lm
    from repro.serving import ServingEngine
    for paged in (False, True):
        eng = ServingEngine(api, params, batch_slots=1, max_len=128,
                            cache_dtype=jnp.bfloat16, paged=paged)
        leaf = jax.tree.leaves(eng.caches)[0]
        assert leaf.dtype == jnp.bfloat16
        eng.reset()
        assert jax.tree.leaves(eng.caches)[0].dtype == jnp.bfloat16
        eng.reset(cache_dtype=jnp.float32)
        assert jax.tree.leaves(eng.caches)[0].dtype == jnp.float32


# ---------------------------------------------------------------------------
# host-side unit checks (allocator, prefix tree, controller)
# ---------------------------------------------------------------------------

def test_allocator_basics():
    a = BlockAllocator(3)
    b0, b1, b2 = a.alloc(), a.alloc(), a.alloc()
    assert sorted([b0, b1, b2]) == [0, 1, 2] and a.alloc() is None
    a.incref(b1)
    assert a.decref(b1) == 1 and a.free_count == 0
    assert a.decref(b1) == 0 and a.free_count == 1
    with pytest.raises(RuntimeError):
        a.decref(b1)                       # double free
    with pytest.raises(RuntimeError):
        a.incref(b1)                       # incref on free block
    a.check()


def test_prefix_tree_chains_do_not_alias():
    a = BlockAllocator(8)
    pc = PrefixCache(a, page=4)
    t1 = np.arange(8, dtype=np.int32)
    t2 = t1.copy()
    t2[1] = 99                             # differs INSIDE page 0
    for toks in (t1, t2):
        for pg in range(2):
            b = a.alloc()
            pc.insert(toks, pg, b)         # tree takes its own reference
            a.decref(b)
    assert len(pc) == 4                    # no node shared across prefixes
    assert pc.lookup(t1) != pc.lookup(t2)
    # same page-1 tokens under different page-0 ⇒ different chained keys
    assert pc.chain_keys(t1)[1] != pc.chain_keys(t2)[1]
    pc.clear()
    a.check()
    assert a.free_count == 8


def test_controller_fork_copy_on_write():
    kv = PagedKVCache(n_slots=2, num_blocks=8, page=4, n_pages=4,
                      prefix_cache=False)
    kv.admit(0, np.arange(5, dtype=np.int32))
    for _ in range(6):                     # fill past one page
        kv.prepare_append(0)
        kv.committed(0)
    kv.fork(1, 0)
    assert kv.allocator.refcount(int(kv.table[0, 0])) == 2
    ops = kv.prepare_append(1)             # shared tail page must CoW
    assert len(ops) == 1 and kv.cow_copies == 1
    assert kv.table[0, 1] != kv.table[1, 1]
    assert kv.table[0, 0] == kv.table[1, 0]    # full page still shared
    kv.check()
    kv.retire(0)
    kv.retire(1)
    kv.check()
    assert kv.allocator.live_count == 0


def test_controller_pool_exhaustion_evicts_then_raises():
    kv = PagedKVCache(n_slots=2, num_blocks=2, page=4, n_pages=4)
    kv.admit(0, np.arange(4, dtype=np.int32))
    for _ in range(4):
        kv.prepare_append(0)
        kv.committed(0)
    kv.seal_prompt_pages(0, np.arange(4, dtype=np.int32), 0)
    kv.retire(0)                           # page lives on in the prefix tree
    assert kv.allocator.live_count == 1
    kv.admit(1, np.full(12, 7, np.int32))  # different prompt: no reuse
    kv.prepare_append(1)
    kv.committed(1, 4)
    kv.prepare_append(1)                   # 2nd block: evicts the LRU leaf
    kv.committed(1, 4)
    assert len(kv.prefix) == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        kv.prepare_append(1)               # 3rd block: nothing left to evict
