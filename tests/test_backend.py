"""Attention-backend registry tests (core/backend.py).

Covers the named-backend API end-to-end:

  * forward + gradient parity of every built-in backend against the "jnp"
    reference, for all four attention entry points (bsa / nsa-causal /
    erwin / full);
  * the GQA-native kernel contract: a parity sweep over rep ∈ {1, 2, 4}
    (Hq = Hkv·rep, K/V passed UN-repeated) for bsa/nsa/erwin on every
    registered backend, fwd + grads, with ragged (per-sample) masks;
  * the optional ``gated_combine`` epilogue op: backends that provide it are
    routed through it, plug-ins without it fall back to the jnp reference;
  * resolution precedence: config < ``use_backend(...)`` context < the
    ``REPRO_ATTENTION_BACKEND`` environment variable;
  * per-branch overrides (``backend_overrides={"slc": ...}``);
  * the plug-in path: a test-only registered counting backend is picked up
    by name and sees exactly the expected per-branch calls;
  * the ``use_kernels`` deprecation shim.

Backends are trace-time state, so every test builds fresh (unjitted or
freshly-jitted) computations.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSAConfig, bsa_attention, bsa_init, erwin_attention,
                        full_attention, nsa_causal_attention, nsa_init)
from repro.core import backend as backend_mod
from repro.core.backend import (JnpBackend, get_backend, list_backends,
                                register_backend, resolve_backend_name,
                                use_backend)

KEY = jax.random.PRNGKey(42)
TOL = dict(atol=1e-3, rtol=1e-3)
CFG_KW = dict(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
              top_k=2, group_size=8)
# "pallas" auto-detects interpret mode on CPU; "interpret" forces it; "auto"
# resolves to "jnp" off-TPU — all are CPU-runnable, so sweep everything.
BACKENDS = ["jnp", "pallas", "interpret", "auto"]


@pytest.fixture(autouse=True)
def _no_env_override(monkeypatch):
    """These tests control resolution explicitly — neutralise CI env legs."""
    monkeypatch.delenv(backend_mod.ENV_VAR, raising=False)


def _qkv(B=2, N=64, Hq=4, Hkv=2, D=16, masked=True):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, N, Hq, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    mask = jnp.ones((B, N), bool).at[:, -N // 8:].set(False) if masked else None
    return q, k, v, mask


def _close(got, want, **kw):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **(kw or TOL))


def _grads_close(got, want):
    gl, wl = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(gl) == len(wl)
    for g, w in zip(gl, wl):
        _close(g, w)


# ---------------------------------------------------------------------------
# fwd + grad parity sweep: every backend vs the jnp reference, all four entry
# points — swapping the backend NAME must change nothing but numerics noise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", BACKENDS)
def test_bsa_parity(name):
    q, k, v, mask = _qkv()
    cfg = BSAConfig(**CFG_KW, backend="jnp")
    params = bsa_init(jax.random.fold_in(KEY, 1), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)

    def loss(cfg):
        return lambda p, q, k, v: jnp.sum(
            bsa_attention(p, q, k, v, cfg=cfg, mask=mask) ** 2)

    cfg_b = dataclasses.replace(cfg, backend=name)
    _close(bsa_attention(params, q, k, v, cfg=cfg_b, mask=mask),
           bsa_attention(params, q, k, v, cfg=cfg, mask=mask))
    got = jax.grad(loss(cfg_b), argnums=(0, 1, 2, 3))(params, q, k, v)
    want = jax.grad(loss(cfg), argnums=(0, 1, 2, 3))(params, q, k, v)
    _grads_close(got, want)


@pytest.mark.parametrize("name", BACKENDS)
def test_nsa_causal_parity(name):
    q, k, v, _ = _qkv(masked=False)
    cfg = BSAConfig(**CFG_KW, backend="jnp")
    params = nsa_init(jax.random.fold_in(KEY, 2), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)

    def loss(cfg):
        return lambda p, q, k, v: jnp.sum(
            nsa_causal_attention(p, q, k, v, cfg=cfg) ** 2)

    cfg_b = dataclasses.replace(cfg, backend=name)
    _close(nsa_causal_attention(params, q, k, v, cfg=cfg_b),
           nsa_causal_attention(params, q, k, v, cfg=cfg))
    got = jax.grad(loss(cfg_b), argnums=(0, 1, 2, 3))(params, q, k, v)
    want = jax.grad(loss(cfg), argnums=(0, 1, 2, 3))(params, q, k, v)
    _grads_close(got, want)


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("level", [0, 1])
def test_erwin_parity(name, level):
    q, k, v, mask = _qkv()

    def loss(backend):
        return lambda q, k, v: jnp.sum(erwin_attention(
            q, k, v, ball_size=32, level=level, mask=mask, backend=backend) ** 2)

    _close(erwin_attention(q, k, v, ball_size=32, level=level, mask=mask,
                           backend=name),
           erwin_attention(q, k, v, ball_size=32, level=level, mask=mask,
                           backend="jnp"))
    got = jax.grad(loss(name), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    _grads_close(got, want)


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("causal", [False, True])
def test_full_attention_parity(name, causal):
    q, k, v, mask = _qkv()

    def loss(backend):
        return lambda q, k, v: jnp.sum(full_attention(
            q, k, v, mask=mask, causal=causal, backend=backend) ** 2)

    _close(full_attention(q, k, v, mask=mask, causal=causal, backend=name),
           full_attention(q, k, v, mask=mask, causal=causal, backend="jnp"))
    got = jax.grad(loss(name), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    _grads_close(got, want)


# ---------------------------------------------------------------------------
# GQA-native kernel contract: rep ∈ {1, 2, 4}, K/V passed UN-repeated, with
# RAGGED masks (two different sample lengths in one packed batch).  The jnp
# backend repeats internally — it pins the semantics every kernel layout
# must reproduce, fwd and grads.
# ---------------------------------------------------------------------------

_GQA_REF_CACHE: dict = {}


def _gqa_case(rep):
    B, N, Hkv, D = 2, 64, 1, 16
    Hq = Hkv * rep
    key = jax.random.fold_in(KEY, 100 + rep)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, N, Hq, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    # ragged: sample 0 full, sample 1 keeps a 40-token prefix
    mask = jnp.stack([jnp.ones(N, bool), jnp.arange(N) < 40])
    cfg = BSAConfig(**CFG_KW, backend="jnp")
    params = {
        "bsa": bsa_init(jax.random.fold_in(key, 1), cfg, n_heads=Hq,
                        n_kv_heads=Hkv, head_dim=D, d_model=Hq * D),
        "nsa": nsa_init(jax.random.fold_in(key, 2), cfg, n_heads=Hq,
                        n_kv_heads=Hkv, head_dim=D, d_model=Hq * D),
    }
    return q, k, v, mask, cfg, params


def _gqa_entry_fns(entry, cfg, params, mask):
    if entry == "bsa":
        return lambda q, k, v: bsa_attention(params["bsa"], q, k, v, cfg=cfg,
                                             mask=mask)
    if entry == "nsa":
        return lambda q, k, v: nsa_causal_attention(params["nsa"], q, k, v,
                                                    cfg=cfg, mask=mask)
    return lambda q, k, v: erwin_attention(q, k, v, ball_size=cfg.ball_size,
                                           mask=mask, backend=cfg.backend)


def _gqa_reference(entry, rep):
    """jnp-backend output + grads, computed once per (entry, rep)."""
    if (entry, rep) not in _GQA_REF_CACHE:
        q, k, v, mask, cfg, params = _gqa_case(rep)
        fn = _gqa_entry_fns(entry, cfg, params, mask)
        out = fn(q, k, v)
        grads = jax.grad(lambda q, k, v: jnp.sum(fn(q, k, v) ** 2),
                         argnums=(0, 1, 2))(q, k, v)
        _GQA_REF_CACHE[(entry, rep)] = (out, grads)
    return _GQA_REF_CACHE[(entry, rep)]


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("rep", [1, 2, 4])
@pytest.mark.parametrize("entry", ["bsa", "nsa", "erwin"])
def test_gqa_parity_sweep(entry, rep, name):
    q, k, v, mask, cfg, params = _gqa_case(rep)
    cfg_b = dataclasses.replace(cfg, backend=name)
    fn = _gqa_entry_fns(entry, cfg_b, params, mask)
    want_out, want_grads = _gqa_reference(entry, rep)
    _close(fn(q, k, v), want_out)
    got = jax.grad(lambda q, k, v: jnp.sum(fn(q, k, v) ** 2),
                   argnums=(0, 1, 2))(q, k, v)
    for g, w in zip(got, want_grads):
        _close(g, w, atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# registry + resolution precedence
# ---------------------------------------------------------------------------

def test_builtins_registered():
    names = list_backends()
    for n in ("jnp", "pallas", "interpret"):
        assert n in names
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert get_backend("auto") is get_backend(expect)


def test_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown attention backend"):
        get_backend("no-such-backend")
    cfg = BSAConfig(**CFG_KW, backend="no-such-backend")   # lazy validation
    q, k, v, mask = _qkv(B=1)
    params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    with pytest.raises(KeyError, match="no-such-backend"):
        bsa_attention(params, q, k, v, cfg=cfg, mask=mask)


def test_invalid_override_key_rejected():
    with pytest.raises(ValueError, match="backend_overrides key"):
        BSAConfig(**CFG_KW, backend_overrides={"flash": "jnp"})


def test_resolution_precedence(monkeypatch):
    # config alone
    assert resolve_backend_name("jnp") == "jnp"
    assert resolve_backend_name(None) == "auto"
    # context beats config
    with use_backend("interpret"):
        assert resolve_backend_name("jnp") == "interpret"
        with use_backend("pallas"):                        # nests, innermost wins
            assert resolve_backend_name("jnp") == "pallas"
        assert resolve_backend_name("jnp") == "interpret"
    # env beats both
    monkeypatch.setenv(backend_mod.ENV_VAR, "jnp")
    with use_backend("pallas"):
        assert resolve_backend_name("interpret") == "jnp"


def test_env_overrides_branch_overrides(monkeypatch):
    cfg = BSAConfig(**CFG_KW, backend="pallas",
                    backend_overrides={"slc": "interpret"})
    monkeypatch.setenv(backend_mod.ENV_VAR, "jnp")
    resolved = backend_mod.resolve_branch_backends(cfg)
    assert all(resolved[b] is get_backend("jnp") for b in ("ball", "cmp", "slc"))


# ---------------------------------------------------------------------------
# plug-in path: a registered counting backend is reachable by NAME from the
# config (base and per-branch) and sees the expected calls
# ---------------------------------------------------------------------------

class CountingBackend:
    """Delegates to the jnp reference, counting trace-time op calls."""

    name = "counting-test"

    def __init__(self):
        self._inner = JnpBackend()
        self.calls = {"ball": 0, "flash": 0, "local_window": 0, "selection": 0}

    def ball(self, *a, **kw):
        self.calls["ball"] += 1
        return self._inner.ball(*a, **kw)

    def flash(self, *a, **kw):
        self.calls["flash"] += 1
        return self._inner.flash(*a, **kw)

    def local_window(self, *a, **kw):
        self.calls["local_window"] += 1
        return self._inner.local_window(*a, **kw)

    def selection(self, *a, **kw):
        self.calls["selection"] += 1
        return self._inner.selection(*a, **kw)


@pytest.fixture
def counting():
    bk = CountingBackend()
    register_backend("counting-test", bk, overwrite=True)
    return bk


def test_registered_plugin_end_to_end(counting):
    q, k, v, mask = _qkv()
    cfg = BSAConfig(**CFG_KW, backend="counting-test")
    params = bsa_init(jax.random.fold_in(KEY, 1), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    out = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    assert counting.calls == {"ball": 1, "flash": 1, "local_window": 0,
                              "selection": 1}
    _close(out, bsa_attention(params, q, k, v,
                              cfg=dataclasses.replace(cfg, backend="jnp"),
                              mask=mask), atol=1e-6, rtol=1e-6)
    # the causal variant routes its local branch through the "ball" slot
    pn = nsa_init(jax.random.fold_in(KEY, 2), cfg, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_model=64)
    nsa_causal_attention(pn, q, k, v, cfg=cfg)
    assert counting.calls["local_window"] == 1


def test_per_branch_override(counting):
    q, k, v, mask = _qkv()
    cfg = BSAConfig(**CFG_KW, backend="jnp",
                    backend_overrides={"slc": "counting-test"})
    params = bsa_init(jax.random.fold_in(KEY, 1), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    out = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    assert counting.calls == {"ball": 0, "flash": 0, "local_window": 0,
                              "selection": 1}
    _close(out, bsa_attention(params, q, k, v,
                              cfg=dataclasses.replace(
                                  cfg, backend_overrides=()), mask=mask),
           atol=1e-6, rtol=1e-6)


def test_register_rejects_bad_plugins():
    with pytest.raises(ValueError, match="reserved"):
        register_backend("auto", JnpBackend())
    with pytest.raises(TypeError, match="protocol"):
        register_backend("broken-test", object())
    with pytest.raises(ValueError, match="already registered"):
        register_backend("jnp", JnpBackend())


# ---------------------------------------------------------------------------
# optional gated_combine epilogue op
# ---------------------------------------------------------------------------

def test_gated_combine_routed_through_backend():
    """A backend providing gated_combine sees the epilogue call; one without
    it (CountingBackend) transparently falls back to the jnp reference."""
    from repro.core.backend import get_combine
    from repro.core.branches import gated_combine_ref

    class CombiningBackend(CountingBackend):
        name = "combining-test"

        def __init__(self):
            super().__init__()
            self.calls["gated_combine"] = 0

        def gated_combine(self, outs, gates, mask):
            self.calls["gated_combine"] += 1
            return gated_combine_ref(outs, gates, mask)

    bk = CombiningBackend()
    register_backend("combining-test", bk, overwrite=True)
    q, k, v, mask = _qkv()
    cfg = BSAConfig(**CFG_KW, backend="combining-test")
    params = bsa_init(jax.random.fold_in(KEY, 1), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    out = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    assert bk.calls["gated_combine"] == 1
    _close(out, bsa_attention(params, q, k, v,
                              cfg=dataclasses.replace(cfg, backend="jnp"),
                              mask=mask), atol=1e-6, rtol=1e-6)

    # a 4-op plug-in (no gated_combine) resolves to the reference epilogue
    plain = CountingBackend()
    assert get_combine(plain) is gated_combine_ref
    assert get_combine(bk) == bk.gated_combine


def test_pallas_gated_combine_matches_reference():
    from repro.core.backend import get_backend
    from repro.core.branches import gated_combine_ref

    B, N, H, D = 2, 32, 4, 16
    ks = jax.random.split(KEY, 6)
    outs = tuple(jax.random.normal(ks[i], (B, N, H, D)) for i in range(3))
    gates = tuple(jax.nn.sigmoid(jax.random.normal(ks[3 + i], (1, 1, H, 1)))
                  for i in range(3))
    mask = jnp.ones((B, N), bool).at[:, -8:].set(False)
    got = get_backend("interpret").gated_combine(outs, gates, mask)
    _close(got, gated_combine_ref(outs, gates, mask), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# use_kernels deprecation shim
# ---------------------------------------------------------------------------

def test_use_kernels_shim_maps_and_warns():
    with pytest.warns(DeprecationWarning, match="use_kernels"):
        cfg = BSAConfig(**CFG_KW, use_kernels=True)
    assert cfg.backend == "pallas" and cfg.use_kernels is None
    with pytest.warns(DeprecationWarning):
        cfg = BSAConfig(**CFG_KW, use_kernels=False)
    assert cfg.backend == "jnp"
    # dataclasses.replace on OTHER fields must not re-warn or clobber
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg2 = dataclasses.replace(cfg, top_k=4)
    assert cfg2.backend == "jnp"
    with pytest.warns(DeprecationWarning):
        cfg3 = dataclasses.replace(cfg2, use_kernels=True)
    assert cfg3.backend == "pallas"
