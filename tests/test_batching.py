"""Ragged-batching pipeline: packing helpers, datasets, batched == per-sample.

The load-bearing invariant: a packed batch of MIXED-SIZE point clouds run
through ``bsa_attention`` in one call equals running every cloud alone —
forward AND gradients, on both the jnp and the Pallas-kernel path.  Nothing
in the model may leak information across the batch dim or out of a sample's
valid prefix.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BSAConfig,
    bsa_attention,
    bsa_init,
    bucket_length,
    pack_ragged,
    unpack_ragged,
)
from repro.core.nsa_causal import nsa_causal_attention, nsa_init

KEY = jax.random.PRNGKey(11)


@pytest.fixture(autouse=True)
def _no_env_override(monkeypatch):
    """The batched==per-sample tests run per NAMED backend (jnp and pallas);
    a CI matrix leg pinning REPRO_ATTENTION_BACKEND would collapse both
    parametrisations onto one backend."""
    monkeypatch.delenv("REPRO_ATTENTION_BACKEND", raising=False)


def _cfg(**kw):
    base = dict(ball_size=16, local_window=16, cmp_block=8, slc_block=8,
                top_k=2, group_size=8)
    base.update(kw)
    return BSAConfig(**base)


def _mixed_batch(sizes, N, Hq=4, Hkv=2, D=16):
    ks = jax.random.split(KEY, 3)
    B = len(sizes)
    q = jax.random.normal(ks[0], (B, N, Hq, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    mask = jnp.stack([jnp.arange(N) < n for n in sizes])
    return q, k, v, mask


# ---------------------------------------------------------------------------
# host-side packing helpers
# ---------------------------------------------------------------------------

def test_bucket_length():
    assert bucket_length(1, 16) == 16
    assert bucket_length(16, 16) == 16
    assert bucket_length(17, 16) == 32
    assert bucket_length(100, 16, geometric=False) == 112
    # geometric: ball count rounds to a power of two → O(log) distinct shapes
    assert bucket_length(100, 16) == 128
    assert bucket_length(129, 16) == 256
    with pytest.raises(ValueError):
        bucket_length(0, 16)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    arrays = [rng.standard_normal((n, 5)).astype(np.float32) for n in (7, 30, 16)]
    batch, mask = pack_ragged(arrays, 16)
    assert batch.shape == (3, 32, 5) and mask.shape == (3, 32)
    assert mask.sum(1).tolist() == [7, 30, 16]
    back = unpack_ragged(batch, mask)
    for a, b in zip(arrays, back):
        np.testing.assert_array_equal(a, b)
    # padding rows are exactly the fill value
    assert np.all(batch[0, 7:] == 0.0)


def test_pack_ragged_pad_to_validation():
    a = [np.zeros((20, 2))]
    batch, _ = pack_ragged(a, 16, pad_to=48)
    assert batch.shape == (1, 48, 2)
    with pytest.raises(ValueError):
        pack_ragged(a, 16, pad_to=16)      # smaller than the sample
    with pytest.raises(ValueError):
        pack_ragged(a, 16, pad_to=50)      # not a ball multiple


def test_dataset_ragged_batches():
    from repro.data import ShapeNetCarDataset
    ds = ShapeNetCarDataset("train", ball_size=32, n_points_range=(70, 120))
    b = next(ds.batches(3, seed=0))
    B, L, F = b["feats"].shape
    assert B == 3 and L % 32 == 0 and F == 7
    lens = b["mask"].sum(1)
    assert lens.min() >= 70 and lens.max() <= 128   # ragged, ball-padded
    assert b["target"].shape == (3, L, 1)
    # masked rows carry no features
    assert np.all(b["feats"][0, int(lens[0]):] == 0.0)
    # pad_to freezes the length across batches (single-jit contract)
    b2 = next(ds.batches(3, seed=1, pad_to=ds.max_padded_len))
    assert b2["feats"].shape[1] == ds.max_padded_len
    # deterministic: same index → same sample, regardless of batching
    s0 = ds[0]
    s0b = ds[0]
    np.testing.assert_array_equal(s0["feats"], s0b["feats"])


# ---------------------------------------------------------------------------
# batched bsa == per-sample loop (fwd + grads, jnp and kernel paths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bsa_batched_equals_per_sample_loop(backend):
    N = 64
    sizes = [64, 40, 24]                    # mixed sizes in one packed batch
    cfg = _cfg(backend=backend)
    q, k, v, mask = _mixed_batch(sizes, N)
    params = bsa_init(jax.random.fold_in(KEY, 1), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    atol = 1e-3 if backend == "pallas" else 1e-5

    def loss(p, q, k, v, m):
        return jnp.sum(bsa_attention(p, q, k, v, cfg=cfg, mask=m) ** 2)

    out_b = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    loss_b, grads_b = jax.value_and_grad(loss)(params, q, k, v, mask)
    gq_b, gk_b, gv_b = jax.grad(loss, argnums=(1, 2, 3))(params, q, k, v, mask)

    loss_s = 0.0
    acc = None
    for i in range(len(sizes)):
        sl = lambda t: t[i:i + 1]
        out_i = bsa_attention(params, sl(q), sl(k), sl(v), cfg=cfg, mask=sl(mask))
        np.testing.assert_allclose(np.asarray(out_b[i]), np.asarray(out_i[0]),
                                   atol=atol, rtol=atol,
                                   err_msg=f"fwd sample {i} (n={sizes[i]})")
        li, gi = jax.value_and_grad(loss)(params, sl(q), sl(k), sl(v), sl(mask))
        gq_i, gk_i, gv_i = jax.grad(loss, argnums=(1, 2, 3))(
            params, sl(q), sl(k), sl(v), sl(mask))
        loss_s += li
        acc = gi if acc is None else jax.tree.map(jnp.add, acc, gi)
        for b_arr, i_arr, nm in ((gq_b, gq_i, "dq"), (gk_b, gk_i, "dk"),
                                 (gv_b, gv_i, "dv")):
            np.testing.assert_allclose(np.asarray(b_arr[i]), np.asarray(i_arr[0]),
                                       atol=atol, rtol=atol,
                                       err_msg=f"{nm} sample {i}")

    np.testing.assert_allclose(float(loss_b), float(loss_s), rtol=1e-5)
    for pb, ps in zip(jax.tree.leaves(grads_b), jax.tree.leaves(acc)):
        np.testing.assert_allclose(np.asarray(pb), np.asarray(ps),
                                   atol=atol, rtol=1e-3)
    # padded query rows are zeroed in the output
    np.testing.assert_allclose(np.asarray(out_b[2, 24:]), 0.0, atol=1e-7)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_nsa_causal_batched_equals_per_sample_loop(backend):
    """Same invariant for the causal LM variant (local-window kernel mask)."""
    N = 64
    sizes = [64, 40]
    cfg = _cfg(backend=backend)
    q, k, v, mask = _mixed_batch(sizes, N)
    params = nsa_init(jax.random.fold_in(KEY, 2), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    atol = 1e-3 if backend == "pallas" else 1e-5
    out_b = nsa_causal_attention(params, q, k, v, cfg=cfg, mask=mask)
    for i in range(len(sizes)):
        sl = lambda t: t[i:i + 1]
        out_i = nsa_causal_attention(params, sl(q), sl(k), sl(v), cfg=cfg,
                                     mask=sl(mask))
        np.testing.assert_allclose(np.asarray(out_b[i]), np.asarray(out_i[0]),
                                   atol=atol, rtol=atol)


def test_local_window_kernel_mask_parity():
    """Masked local kernel == masked jnp reference (fwd + grads)."""
    from repro.kernels import ops, ref
    B, N, H, D, w = 2, 64, 2, 16, 16
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (B, N, H, D)) for kk in ks)
    mask = jnp.ones((B, N), bool).at[0, 40:].set(False).at[1, 25:].set(False)

    def make_loss(fn):
        def loss(q, k, v):
            o = fn(q, k, v)
            return jnp.sum(jnp.where(mask[:, :, None, None], o, 0.0) ** 2)
        return loss

    out = ops.local_window_attention(q, k, v, w, mask=mask)
    want = ref.local_window_attention_ref(q, k, v, w, mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    g_k = jax.grad(make_loss(
        lambda q, k, v: ops.local_window_attention(q, k, v, w, mask=mask)),
        argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(make_loss(
        lambda q, k, v: ref.local_window_attention_ref(q, k, v, w, mask=mask)),
        argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g_k, g_r, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3, err_msg=nm)
    # masked keys get exactly zero gradient
    np.testing.assert_allclose(np.asarray(g_k[1][0, 40:]), 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# serving: ragged clouds end-to-end
# ---------------------------------------------------------------------------

def test_geometry_engine_matches_solo_forward():
    import dataclasses

    from repro.configs import get_config
    from repro.models.api import model_api
    from repro.serving import GeometryEngine

    mcfg = get_config("shapenet-bsa").scaled(
        n_layers=2, d_model=32, n_heads=2, head_dim=16, n_kv_heads=2, d_ff=64)
    mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, ball_size=16,
                                               local_window=16))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = GeometryEngine(api, params, batch_slots=3)

    rng = np.random.default_rng(3)
    clouds = []
    for n in (20, 45, 33, 11):              # forces a short final batch too
        pts = rng.standard_normal((n, 3)).astype(np.float32)
        feats = rng.standard_normal((n, mcfg.in_dim)).astype(np.float32)
        clouds.append((pts, feats))

    outs = eng.predict(clouds)
    assert [o.shape for o in outs] == [(20, 1), (45, 1), (33, 1), (11, 1)]
    assert eng.clouds_served == 4 and eng.points_served == 20 + 45 + 33 + 11
    # every batched prediction equals serving the cloud alone
    for c, o in zip(clouds, outs):
        solo = eng.predict([c])[0]
        np.testing.assert_allclose(solo, o, atol=1e-5, rtol=1e-5)
