"""Substrate tests: optimizer math, checkpoint roundtrip + reshard, trainer
loss-decrease + resume, watchdog, serving decode == teacher forcing,
gradient compression, data pipelines."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.launch.mesh import make_mesh
from repro.configs import get_config
from repro.configs.reduce import SMOKE_SEQ, smoke_config
from repro.data import ElasticityDataset, ShapeNetCarDataset, lm_batches
from repro.models.api import model_api
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.runtime import Trainer, TrainerConfig, Watchdog
from repro.serving import ServingEngine


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference():
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw_init(p)
    p2, st2 = adamw_update(p, g, st, lr=0.1, weight_decay=0.0)
    # first step: mhat = g, vhat = g², delta ≈ sign(g)
    want = p["w"] - 0.1 * g["w"] / (jnp.abs(g["w"]) + 1e-8)
    np.testing.assert_allclose(p2["w"], want, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_adamw_weight_decay_decoupled():
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    st = adamw_init(p)
    p2, _ = adamw_update(p, g, st, lr=0.1, weight_decay=0.01)
    np.testing.assert_allclose(p2["w"], 10.0 - 0.1 * 0.01 * 10.0, rtol=1e-6)


def test_cosine_schedule():
    assert float(cosine_schedule(0, base_lr=1.0, total_steps=100, warmup_steps=10)) == 0.0
    assert float(cosine_schedule(10, base_lr=1.0, total_steps=100, warmup_steps=10)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, base_lr=1.0, total_steps=100, warmup_steps=10)) == pytest.approx(0.0, abs=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    from repro.optim import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": jnp.zeros((2, 3)), "step": jnp.array(7, jnp.int32)}}
    for s in (10, 20, 30):
        mgr.save(s, state, extra={"tag": s})
    assert latest_step(tmp_path) == 30
    # pruned to keep_last=2
    assert sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()) == [20, 30]
    got, meta = mgr.restore(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    assert meta["step"] == 30 and meta["extra"]["tag"] == 30
    np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3, async_save=True)
    mgr.save(1, {"w": jnp.ones((8,))})
    mgr.wait()
    assert latest_step(tmp_path) == 1


def test_checkpoint_reshard_on_restore(tmp_path):
    """Elastic restart: save unsharded, restore with a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(5, state)
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = mgr.restore(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state), shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(state["w"]))
    assert got["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_straggler_detection():
    events = []
    wd = Watchdog(straggler_factor=2.0,
                  on_straggler=lambda s, d, e: events.append((s, d)))
    for i in range(10):
        wd.step(i, 0.1)
    wd.step(10, 0.5)          # 5× slower than EWMA → straggler
    assert len(events) == 1 and events[0][0] == 10
    wd.step(11, 0.1)          # baseline not poisoned
    assert len(events) == 1


# ---------------------------------------------------------------------------
# trainer end-to-end (tiny LM): loss decreases, checkpoint resume
# ---------------------------------------------------------------------------

def _tiny_lm():
    m = smoke_config(get_config("tinyllama-1.1b"))
    return m, model_api(m)


def test_trainer_loss_decreases_and_resumes(tmp_path):
    mcfg, api = _tiny_lm()
    cfg = TrainerConfig(base_lr=3e-3, total_steps=40, warmup_steps=2,
                        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100)
    tr = Trainer(api, cfg)
    data = lm_batches(vocab_size=mcfg.vocab_size, batch_size=2,
                      seq_len=SMOKE_SEQ, seed=0)
    params, opt = tr.fit(data, steps=21)
    losses = [m["loss"] for m in tr.metrics_history]
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"
    assert latest_step(tmp_path) is not None

    # resume: new trainer picks up the checkpoint and continues
    tr2 = Trainer(api, cfg)
    data2 = lm_batches(vocab_size=mcfg.vocab_size, batch_size=2,
                       seq_len=SMOKE_SEQ, seed=0, start_step=21)
    p2, o2 = tr2.fit(data2, steps=2)
    assert int(o2["step"]) >= 22  # optimizer steps continued from restore


# ---------------------------------------------------------------------------
# serving: decode replay == teacher forcing
# ---------------------------------------------------------------------------

def test_serving_matches_teacher_forcing():
    mcfg, api = _tiny_lm()
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mcfg.vocab_size, (2, 32), dtype=np.int32)

    eng = ServingEngine(api, params, batch_slots=2, max_len=SMOKE_SEQ)
    gen = eng.generate(prompts, n_tokens=4)
    assert gen.shape == (2, 4)

    # teacher-forced reference: greedy tokens from the train-path logits
    import jax.numpy as jnp
    from repro.models.transformer import lm_apply
    toks = jnp.asarray(prompts)
    logits, _ = lm_apply(params, toks, mcfg=mcfg)
    want_first = np.asarray(jnp.argmax(logits[:, -1], -1))
    np.testing.assert_array_equal(gen[:, 0], want_first)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compressed_psum_error_feedback():
    from repro.optim.compress import _dequantize, _quantize
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q, s, resid = _quantize(g)
    deq = _dequantize(q, s, g.shape)
    np.testing.assert_allclose(np.asarray(deq + resid), np.asarray(g), atol=1e-6)
    # quantization error bounded by scale/2 per element
    assert float(jnp.abs(resid).max()) <= float(s.max()) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# data pipelines
# ---------------------------------------------------------------------------

def test_shapenet_dataset_shapes_and_determinism():
    ds = ShapeNetCarDataset("train", ball_size=256)
    a, b = ds[3], ds[3]
    assert a["feats"].shape == (3840, 7)          # 3586 → 15 balls of 256
    assert a["mask"].sum() == 3586
    np.testing.assert_array_equal(a["feats"], b["feats"])
    batch = next(ds.batches(2, seed=0))
    assert batch["feats"].shape == (2, 3840, 7)
    assert np.isfinite(batch["target"]).all()


def test_elasticity_dataset():
    ds = ElasticityDataset("test", ball_size=256)
    it = ds[0]
    assert it["feats"].shape == (1024, 6) and it["mask"].sum() == 972


def test_lm_batches_deterministic_restart():
    a = list(zip(range(3), lm_batches(vocab_size=100, batch_size=2, seq_len=16, seed=5)))
    b = next(lm_batches(vocab_size=100, batch_size=2, seq_len=16, seed=5, start_step=2))
    np.testing.assert_array_equal(a[2][1]["tokens"], b["tokens"])
