"""Tile-occupancy + precision-contract tests (kernels/occupancy.py).

Three seams of the block-skipping facility:

  * the host-side live-map builders (key/query/causal tile liveness, packed
    segment ranges, dead-group invalidation) — pure functions, exact
    expectations;
  * dead-tile CORRECTNESS — on adversarial ragged mixes whole (q-tile,
    k-tile) pairs die and the kernels ``pl.when``-skip them, forward and
    backward; outputs and grads must still match the jnp oracle, with the
    skipped rows EXACTLY zero on both sides;
  * the occupancy recorder + the measured tile reduction on the acceptance
    mix (sizes 256/192/128/64, ball/window/tile 64): ≥ 25 % fewer computed
    tiles on the local and flash paths.

Plus the ``score_dtype`` precision contract end-to-end (bf16 through
``bsa_attention`` / ``nsa_causal_attention`` on padded AND packed layouts),
the fp8 experiment gate (``REPRO_FP8=1``), and the config normalization of
dtype-object spellings.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSAConfig, bsa_attention, bsa_attention_varlen,
                        bsa_init, nsa_causal_attention, nsa_init)
from repro.kernels import occupancy, ops, ref
from repro.kernels.common import (fp8_enabled, mma_dtype,
                                  resolve_compute_dtype)
from repro.numerics import NEG_INF, key_padding_bias

KEY = jax.random.PRNGKey(7)

# the acceptance mix: high-variance ragged sizes, ball/window/tile 64
MIX = [256, 192, 128, 64]
BALL = 64


@pytest.fixture(autouse=True)
def _no_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_ATTENTION_BACKEND", raising=False)


def _mix_mask(N=256):
    return jnp.stack([jnp.arange(N) < n for n in MIX])


def _qkv(B, N, Hq, Hkv, D, fold=0):
    ks = jax.random.split(jax.random.fold_in(KEY, fold), 3)
    return (jax.random.normal(ks[0], (B, N, Hq, D)),
            jax.random.normal(ks[1], (B, N, Hkv, D)),
            jax.random.normal(ks[2], (B, N, Hkv, D)))


def _pack(mask_lens, *tensors, ball=BALL):
    """Concatenate per-sample ball-padded slices → (packed tensors, offsets,
    packed mask)."""
    padded = [-(-n // ball) * ball for n in mask_lens]
    offs = np.concatenate([[0], np.cumsum(padded)]).astype(np.int32)
    packed = [jnp.concatenate([t[i, :padded[i]] for i in range(len(padded))])
              for t in tensors]
    maskp = jnp.concatenate(
        [jnp.arange(padded[i]) < mask_lens[i] for i in range(len(padded))])
    return packed, jnp.asarray(offs), maskp


# ---------------------------------------------------------------------------
# Live-map builders
# ---------------------------------------------------------------------------

def test_key_tile_live_from_bias():
    mask = _mix_mask()
    kb = key_padding_bias(mask, 4, 256)
    live = np.asarray(occupancy.key_tile_live(kb, 64))
    want = np.array([[1, 1, 1, 1], [1, 1, 1, 0], [1, 1, 0, 0], [1, 0, 0, 0]],
                    bool)
    np.testing.assert_array_equal(live, want)


def test_causal_tile_live():
    # causal: k-tile j live for q-tile i iff its first key <= last query
    live = occupancy.causal_tile_live(4, 4, 64, 64, causal=True,
                                      block_causal=False, ell=1)
    np.testing.assert_array_equal(live, np.tril(np.ones((4, 4), bool)))
    # block-causal (ell=8): tile pairs where no block ends before any query die
    live = occupancy.causal_tile_live(4, 4, 64, 8, causal=False,
                                      block_causal=True, ell=8)
    assert live.shape == (4, 4)
    assert not live[0, 1] and live[1, 0] and live[3, 3]


def test_packed_segment_ranges():
    offs = jnp.asarray([0, 128, 192, 256], jnp.int32)
    from repro.numerics import segment_ids_from_offsets
    seg = segment_ids_from_offsets(offs, 256)
    qr = occupancy.tile_seg_ranges(seg, 64)
    live = np.asarray(occupancy.ranges_live_map(qr, qr))
    # tiles: [s0, s0, s1, s2] — live iff segment ranges overlap
    want = np.array([[1, 1, 0, 0], [1, 1, 0, 0], [0, 0, 1, 0], [0, 0, 0, 1]],
                    bool)
    np.testing.assert_array_equal(live, want)


def test_invalidate_dead_groups():
    # 2 samples × 4 groups of 8 tokens; sample 1 has only 8 valid tokens
    mask = jnp.stack([jnp.ones(32, bool), jnp.arange(32) < 8])
    sel_valid = jnp.ones((2, 4, 1, 2), bool)
    out = np.asarray(occupancy.invalidate_dead_groups(sel_valid, mask, 32))
    assert out[0].all()                      # all groups of sample 0 live
    np.testing.assert_array_equal(out[1, :, 0, 0], [True, False, False, False])
    # mask None → pass-through
    assert occupancy.invalidate_dead_groups(sel_valid, None, 32) is sel_valid


# ---------------------------------------------------------------------------
# Recorder
# ---------------------------------------------------------------------------

def test_recorder_counts_and_nesting():
    with occupancy.record_occupancy() as outer:
        occupancy.record("k", jnp.asarray([[1, 0], [1, 1]], jnp.int32))
        with occupancy.record_occupancy() as inner:
            occupancy.record("k", jnp.asarray([0, 1], jnp.int32))
        occupancy.record("k", jnp.asarray([1], jnp.int32))
    assert outer == {"k": {"live": 4, "total": 5}}
    assert inner == {"k": {"live": 1, "total": 2}}


def test_recorder_is_noop_under_tracing():
    @jax.jit
    def f(x):
        occupancy.record("traced", x > 0)
        return x

    with occupancy.record_occupancy() as counts:
        f(jnp.ones((4,)))
    assert counts == {}


# ---------------------------------------------------------------------------
# Acceptance: ≥ 25 % fewer computed tiles on local + flash, with parity
# ---------------------------------------------------------------------------

def test_tile_reduction_on_acceptance_mix():
    B, N, H, D = 4, 256, 2, 32
    mask = _mix_mask(N)
    q, k, v = _qkv(B, N, H, H, D)

    with occupancy.record_occupancy() as c:
        ops.local_window_attention(q, k, v, BALL, mask, interpret=True)
    loc = c["local"]
    assert loc == {"live": 19, "total": 32}
    assert loc["live"] / loc["total"] <= 0.75      # ≥ 25 % fewer

    (qp, kp, vp), offs, maskp = _pack(MIX, q, k, v)
    with occupancy.record_occupancy() as c:
        ops.flash_attention_varlen(qp, kp, vp, offs, offs, key_valid=maskp,
                                   tq=64, tk=64, interpret=True)
    fl = c["varlen_flash"]
    assert fl == {"live": 30, "total": 100}
    assert fl["live"] / fl["total"] <= 0.75        # ≥ 25 % fewer

    with occupancy.record_occupancy() as c:
        ops.flash_attention(q, k, v, key_valid=mask, q_valid=mask,
                            tq=64, tk=64, interpret=True)
    fp = c["flash"]
    assert fp == {"live": 30, "total": 64}

    with occupancy.record_occupancy() as c:
        ops.ball_attention(q, k, v, mask, BALL, interpret=True)
    assert c["bta"] == {"live": 10, "total": 16}


# ---------------------------------------------------------------------------
# Dead-tile correctness: skipped tiles match the jnp oracle EXACTLY
# ---------------------------------------------------------------------------

def test_ball_dead_tiles_exact():
    B, N, H, D = 4, 256, 2, 32
    mask = _mix_mask(N)
    q, k, v = _qkv(B, N, H, H, D, fold=1)
    w = jax.random.normal(jax.random.fold_in(KEY, 11), (B, N, H, D))

    def kf(q, k, v):
        return jnp.sum(ops.ball_attention(q, k, v, mask, BALL,
                                          interpret=True) * w)

    def rf(q, k, v):
        return jnp.sum(ref.ball_attention_ref(q, k, v, mask, BALL) * w)

    out_k = ops.ball_attention(q, k, v, mask, BALL, interpret=True)
    out_r = ref.ball_attention_ref(q, k, v, mask, BALL)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5, rtol=1e-5)
    # dead balls (every key masked) → EXACT zeros on both sides
    dead = ~np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(out_k)[dead], 0.0)
    np.testing.assert_array_equal(np.asarray(out_r)[dead], 0.0)

    gk = jax.grad(kf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(rf, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)
    # grads of dead rows exactly zero (skipped in the fused backward)
    np.testing.assert_array_equal(np.asarray(gk[0])[dead], 0.0)
    np.testing.assert_array_equal(np.asarray(gk[1])[dead], 0.0)
    np.testing.assert_array_equal(np.asarray(gk[2])[dead], 0.0)


def test_local_dead_tiles_exact():
    B, N, H, D = 4, 256, 2, 32
    mask = _mix_mask(N)
    q, k, v = _qkv(B, N, H, H, D, fold=2)
    w = jax.random.normal(jax.random.fold_in(KEY, 12), (B, N, H, D))

    out_k = ops.local_window_attention(q, k, v, BALL, mask, interpret=True)
    out_r = ref.local_window_attention_ref(q, k, v, BALL, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5, rtol=1e-5)
    # rows whose self AND prev key halves are fully dead → exact zeros:
    # sample 3 (64 valid): blocks 2..3 have dead self keys and dead prev keys
    np.testing.assert_array_equal(np.asarray(out_k)[3, 128:], 0.0)
    np.testing.assert_array_equal(np.asarray(out_r)[3, 128:], 0.0)

    def kf(q, k, v):
        return jnp.sum(ops.local_window_attention(q, k, v, BALL, mask,
                                                  interpret=True) * w)

    def rf(q, k, v):
        return jnp.sum(ref.local_window_attention_ref(q, k, v, BALL, mask) * w)

    gk = jax.grad(kf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(rf, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(gk[0])[3, 128:], 0.0)
    # masked-key columns get exactly zero dK/dV
    np.testing.assert_array_equal(np.asarray(gk[1])[3, 64:], 0.0)
    np.testing.assert_array_equal(np.asarray(gk[2])[3, 64:], 0.0)


def test_flash_q_valid_dead_tiles_zero_with_parity_on_valid_rows():
    """q_valid is an optimization HINT: rows it kills are UNSPECIFIED in the
    contract (the jnp oracle ignores it; kernels skip dead q-tiles and leave
    zeros).  Valid rows must agree; kernel dead rows must be exactly zero."""
    B, N, H, D = 4, 256, 2, 32
    mask = _mix_mask(N)
    q, k, v = _qkv(B, N, H, H, D, fold=3)

    out_k = ops.flash_attention(q, k, v, key_valid=mask, q_valid=mask,
                                tq=64, tk=64, interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, key_valid=mask)
    valid = np.asarray(mask)
    np.testing.assert_allclose(np.asarray(out_k)[valid],
                               np.asarray(out_r)[valid],
                               atol=1e-5, rtol=1e-5)
    # fully-dead q tiles are skipped → exact zeros (sample 3: rows 64+)
    np.testing.assert_array_equal(np.asarray(out_k)[3, 64:], 0.0)

    w = jax.random.normal(jax.random.fold_in(KEY, 13), (B, N, H, D))
    # grads: only valid rows contribute to a correctly-masked loss
    wm = w * mask[..., None, None]

    def kf(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, key_valid=mask,
                                           q_valid=mask, tq=64, tk=64,
                                           interpret=True) * wm)

    def rf(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, key_valid=mask) * wm)

    gk = jax.grad(kf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(rf, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_selection_dead_group_invalidation_exact():
    """Groups whose query tokens are all padded have every selection
    invalidated — kernel skips them, oracle zeroes them, both exactly."""
    B, N, Hkv, D, ell, g, ks = 4, 256, 2, 32, 8, 8, 4
    mask = _mix_mask(N)
    q, k, v = _qkv(B, N, Hkv, Hkv, D, fold=4)
    G, nb = N // g, N // ell
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 14))
    idx = jax.random.randint(k1, (B, G, Hkv, ks), 0, nb)
    valid = jax.random.bernoulli(k2, 0.9, (B, G, Hkv, ks))

    out_k = ops.selection_attention(q, k, v, idx, valid, mask,
                                    block_size=ell, group_size=g,
                                    interpret=True)
    out_r = ref.selection_attention_ref(q, k, v, idx, valid, mask,
                                        block_size=ell, group_size=g)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5, rtol=1e-5)
    dead = ~np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(out_k)[dead], 0.0)
    np.testing.assert_array_equal(np.asarray(out_r)[dead], 0.0)

    w = jax.random.normal(jax.random.fold_in(KEY, 15), (B, N, Hkv, D))

    def kf(q, k, v):
        return jnp.sum(ops.selection_attention(
            q, k, v, idx, valid, mask, block_size=ell, group_size=g,
            interpret=True) * w)

    def rf(q, k, v):
        return jnp.sum(ref.selection_attention_ref(
            q, k, v, idx, valid, mask, block_size=ell, group_size=g) * w)

    gk = jax.grad(kf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(rf, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(gk[0])[dead], 0.0)


def test_varlen_flash_dead_tiles_parity():
    """Packed layout on the acceptance mix: 70 % of tiles skip, forward and
    grads still match the padded oracle sample-by-sample."""
    B, N, H, D = 4, 256, 2, 32
    mask = _mix_mask(N)
    q, k, v = _qkv(B, N, H, H, D, fold=5)
    (qp, kp, vp), offs, maskp = _pack(MIX, q, k, v)
    w = jax.random.normal(jax.random.fold_in(KEY, 16), qp.shape)

    def kf(qp, kp, vp):
        return jnp.sum(ops.flash_attention_varlen(
            qp, kp, vp, offs, offs, key_valid=maskp, tq=64, tk=64,
            interpret=True) * w)

    out_p = ops.flash_attention_varlen(qp, kp, vp, offs, offs,
                                       key_valid=maskp, tq=64, tk=64,
                                       interpret=True)
    gk = jax.grad(kf, argnums=(0, 1, 2))(qp, kp, vp)
    # oracle: per-sample dense flash on the padded layout
    o = np.asarray(offs)
    for i in range(B):
        sl = slice(o[i], o[i + 1])
        n = o[i + 1] - o[i]
        out_i = ref.flash_attention_ref(q[i:i + 1, :n], k[i:i + 1, :n],
                                        v[i:i + 1, :n],
                                        key_valid=mask[i:i + 1, :n])
        np.testing.assert_allclose(np.asarray(out_p[sl]), np.asarray(out_i[0]),
                                   atol=1e-5, rtol=1e-5)

        def rf(qi, ki, vi):
            return jnp.sum(ref.flash_attention_ref(
                qi, ki, vi, key_valid=mask[i:i + 1, :n]) * w[sl][None])

        gr = jax.grad(rf, argnums=(0, 1, 2))(q[i:i + 1, :n], k[i:i + 1, :n],
                                             v[i:i + 1, :n])
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a[sl]), np.asarray(b[0]),
                                       atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Precision contract end-to-end + fp8 gate + config normalization
# ---------------------------------------------------------------------------

def _mostly_close(a, b, tol=5e-2, frac=0.98):
    """Elementwise relative closeness for ≥ ``frac`` of elements.  fp32 vs
    bf16 runs legitimately differ WHERE bf16 scoring flips a top-k selection
    (a discrete choice) — only isolated elements, so the bulk must agree."""
    rel = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)) \
        / (np.abs(np.asarray(a, np.float32)) + 1.0)
    assert float(np.mean(rel < tol)) >= frac, \
        f"only {np.mean(rel < tol):.3f} of elements within {tol}"


def test_bf16_end_to_end_padded_and_packed():
    B, N, Hq, Hkv, D, dm = 2, 128, 4, 2, 32, 64
    cfg = BSAConfig(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
                    top_k=2, group_size=8, backend="interpret")
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, N, Hq, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    mask = jnp.stack([jnp.arange(N) < 96, jnp.arange(N) < 64])
    params = bsa_init(ks[3], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                      d_model=dm)
    cfg_b = dataclasses.replace(cfg, score_dtype="bfloat16")
    cfg_bj = dataclasses.replace(cfg_b, backend="jnp")

    o32 = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    ob = bsa_attention(params, q, k, v, cfg=cfg_b, mask=mask)
    obj = bsa_attention(params, q, k, v, cfg=cfg_bj, mask=mask)
    assert ob.dtype == jnp.float32            # cast back to the input dtype
    # kernel-vs-jnp at the SAME precision: identical selections, tight bound
    np.testing.assert_allclose(np.asarray(ob), np.asarray(obj),
                               atol=2e-2, rtol=2e-2)
    # fp32-vs-bf16 drift: bulk within bf16 tolerance (flips are discrete)
    _mostly_close(o32, ob)

    # packed-varlen layout
    lens = [96, 64]
    (qp, kp, vp), offs, maskp = _pack(lens, q, k, v, ball=32)
    o32p = bsa_attention_varlen(params, qp, kp, vp, cfg=cfg, offsets=offs,
                                mask=maskp)
    obp = bsa_attention_varlen(params, qp, kp, vp, cfg=cfg_b, offsets=offs,
                               mask=maskp)
    objp = bsa_attention_varlen(params, qp, kp, vp, cfg=cfg_bj, offsets=offs,
                                mask=maskp)
    assert obp.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(obp), np.asarray(objp),
                               atol=2e-2, rtol=2e-2)
    _mostly_close(o32p, obp)

    # causal stack; bf16 INPUTS stay bf16 on the way out
    nparams = nsa_init(ks[4], cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                       d_model=dm)
    n32 = nsa_causal_attention(nparams, q, k, v, cfg=cfg, mask=mask)
    nb = nsa_causal_attention(nparams, q, k, v, cfg=cfg_b, mask=mask)
    nbj = nsa_causal_attention(nparams, q, k, v, cfg=cfg_bj, mask=mask)
    np.testing.assert_allclose(np.asarray(nb), np.asarray(nbj),
                               atol=2e-2, rtol=2e-2)
    _mostly_close(n32, nb)
    nbi = nsa_causal_attention(
        nparams, q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
        v.astype(jnp.bfloat16), cfg=cfg_b, mask=mask)
    assert nbi.dtype == jnp.bfloat16


def test_compute_dtype_resolution_and_fp8_gate(monkeypatch):
    monkeypatch.delenv("REPRO_FP8", raising=False)
    assert resolve_compute_dtype(jnp.float32) == "float32"
    assert resolve_compute_dtype(jnp.bfloat16) == "bfloat16"
    assert not fp8_enabled()
    assert mma_dtype("float32") == "float32"
    assert mma_dtype("bfloat16") == "bfloat16"

    monkeypatch.setenv("REPRO_FP8", "1")
    assert fp8_enabled()
    assert resolve_compute_dtype(jnp.float32) == "float32"  # fp8 is sub-fp32 only
    got = resolve_compute_dtype(jnp.bfloat16)
    if hasattr(jnp, "float8_e4m3fn"):
        assert got == "float8_e4m3fn"
        # fp8 is QK^T-only: every OTHER matmul operand stays ≥ 16 bits
        assert mma_dtype(got) == "bfloat16"
    else:
        assert got == "bfloat16"


def test_fp8_flash_experiment(monkeypatch):
    """REPRO_FP8=1 + bf16 inputs → fp8 QK^T operands.  Interpret-mode CPU
    support for fp8 dots is best-effort; skip (not fail) if the backend
    can't lower it."""
    if not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("jax build has no float8_e4m3fn")
    monkeypatch.setenv("REPRO_FP8", "1")
    B, N, H, D = 1, 128, 2, 32
    q, k, v = _qkv(B, N, H, H, D, fold=6)
    dt = jnp.bfloat16
    try:
        out = ops.flash_attention(q.astype(dt), k.astype(dt), v.astype(dt),
                                  interpret=True)
    except Exception as e:                    # pragma: no cover - backend dep
        pytest.skip(f"fp8 dot unsupported under interpret mode: {e}")
    ref_out = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out), atol=0.25, rtol=0.25)


def test_config_score_dtype_accepts_dtype_objects():
    assert BSAConfig(score_dtype=jnp.bfloat16).score_dtype == "bfloat16"
    assert BSAConfig(score_dtype=np.float32).score_dtype == "float32"
    assert BSAConfig(score_dtype=jnp.dtype("bfloat16")).score_dtype == "bfloat16"
    assert BSAConfig(score_dtype="float32").score_dtype == "float32"
    with pytest.raises(ValueError, match="float32.*bfloat16"):
        BSAConfig(score_dtype="float16")      # valid dtype, not a tested one
    with pytest.raises(ValueError, match="float32.*bfloat16"):
        BSAConfig(score_dtype="not-a-dtype")


def test_dead_key_bias_matches_neg_inf_contract():
    """The liveness threshold (NEG_INF/2) matches the masking contract: a
    tile is dead exactly when every one of its key biases is ≤ NEG_INF/2."""
    kb = jnp.full((1, 128), NEG_INF)
    assert not np.asarray(occupancy.key_tile_live(kb, 64)).any()
    kb = kb.at[0, 127].set(0.0)
    np.testing.assert_array_equal(
        np.asarray(occupancy.key_tile_live(kb, 64))[0], [False, True])


def test_ring_hop_live_token_causal_rule():
    from repro.kernels.occupancy import ring_hop_live
    p = 8
    live = ring_hop_live(p, 16, causal=True)
    # hop h on shard i holds the slab of shard (i-h) mod p; token-causal
    # keeps exactly the hops that stay at-or-behind the local slab: h <= i
    i = np.arange(p)[:, None]
    h = np.arange(p)[None, :]
    assert np.array_equal(live, h <= i)
    assert live.sum() == p * (p + 1) // 2          # ~half of p*p hops
    # non-causal: every hop contributes
    assert ring_hop_live(p, 16).all()


def test_cached_varlen_maps_lru_and_parity():
    from repro.kernels.occupancy import (_varlen_maps, cached_varlen_maps,
                                         offsets_digest, tile_seg_ranges)
    from repro.numerics import segment_ids_from_offsets
    offs = jnp.asarray([0, 96, 128], jnp.int32)
    _varlen_maps.cache_clear()
    qseg, kseg, qrng, krng = cached_varlen_maps(offs, offs, 128, 128, 32, 32)
    assert _varlen_maps.cache_info().misses == 1
    cached_varlen_maps(offs, offs, 128, 128, 32, 32)
    assert _varlen_maps.cache_info().hits == 1      # second call is a hit
    # cached numpy build == the traced jnp build
    ref_seg = segment_ids_from_offsets(offs, 128)
    assert np.array_equal(np.asarray(qseg), np.asarray(ref_seg))
    assert np.array_equal(np.asarray(qrng),
                          np.asarray(tile_seg_ranges(ref_seg, 32)))
    # tracers bypass the cache (digest None) but produce the same arrays
    assert offsets_digest(offs) == (0, 96, 128)
    traced = jax.jit(lambda o: cached_varlen_maps(o, o, 128, 128, 32, 32)[0])(offs)
    assert np.array_equal(np.asarray(traced), np.asarray(ref_seg))
