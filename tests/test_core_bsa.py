"""Core BSA behaviour + property tests (hypothesis) on the system invariants."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test]); skipping module")
from hypothesis import given, settings, strategies as st

from repro.core import (
    BSAConfig,
    bsa_attention,
    bsa_init,
    full_attention,
    init_decode_cache,
    nsa_causal_attention,
    nsa_causal_decode,
    nsa_init,
)
from repro.core.balltree import build_balltree_permutation, pad_to_multiple

KEY = jax.random.PRNGKey(7)


def _qkv(B=2, N=256, Hq=4, Hkv=2, D=16):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, N, Hq, D)),
            jax.random.normal(ks[1], (B, N, Hkv, D)),
            jax.random.normal(ks[2], (B, N, Hkv, D)))


def _cfg(**kw):
    base = dict(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
                top_k=2, group_size=8)
    base.update(kw)
    return BSAConfig(**base)


# ---------------------------------------------------------------------------
# ball tree properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 500), d=st.integers(2, 4), m=st.sampled_from([8, 16, 32]))
def test_balltree_is_permutation(n, d, m):
    pts = np.random.default_rng(n).standard_normal((n, d))
    perm = build_balltree_permutation(pts, m)
    assert sorted(perm.tolist()) == list(range(n))


def test_balltree_balls_are_spatially_compact():
    """Mean intra-ball distance must beat random grouping by a wide margin."""
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((1024, 3))
    m = 64
    perm = build_balltree_permutation(pts, m)
    ordered = pts[perm]

    def mean_radius(p):
        balls = p.reshape(-1, m, 3)
        c = balls.mean(1, keepdims=True)
        return float(np.linalg.norm(balls - c, axis=-1).mean())

    assert mean_radius(ordered) < 0.6 * mean_radius(pts)


def test_pad_to_multiple():
    x = np.ones((10, 3))
    p, mask = pad_to_multiple(x, 8)
    assert p.shape == (16, 3) and mask.sum() == 10 and not mask[10:].any()


# ---------------------------------------------------------------------------
# gating / branch behaviour
# ---------------------------------------------------------------------------

def test_gates_mix_branches():
    q, k, v = _qkv()
    cfg = _cfg()
    params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    out, aux = bsa_attention(params, q, k, v, cfg=cfg, return_aux=True)
    g = aux["gates"]
    # gates init at σ(0)=0.5 ⇒ output = 0.5·(ball+cmp+slc)
    want = 0.5 * (aux["ball"].astype(jnp.float32) + aux["cmp"].astype(jnp.float32)
                  + aux["slc"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_own_ball_masking_excludes_local_blocks():
    q, k, v = _qkv()
    cfg = _cfg(mask_own_ball=True)
    params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    _, aux = bsa_attention(params, q, k, v, cfg=cfg, return_aux=True)
    idx = np.asarray(aux["indices"])                 # (B, G, Hkv, k*)
    G = idx.shape[1]
    g_tokens = 256 // G
    blocks_per_ball = cfg.ball_size // cfg.cmp_block
    for p in range(G):
        ball = (p * g_tokens) // cfg.ball_size
        own = set(range(ball * blocks_per_ball, (ball + 1) * blocks_per_ball))
        assert not (set(idx[:, p].reshape(-1).tolist()) & own), \
            f"group {p} selected its own ball"


def test_group_selection_shares_indices_within_group():
    """g=1 (no grouping) vs g=8: grouped indices are constant within groups
    by construction; check variant parity of output shapes + finiteness."""
    q, k, v = _qkv()
    for gs in (0, 8):
        cfg = _cfg(group_size=gs, query_cmp_selection=False)
        params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
        out = bsa_attention(params, q, k, v, cfg=cfg)
        assert out.shape == q.shape and bool(jnp.isfinite(out).all())


def test_padding_tokens_produce_zero_output_and_no_nan():
    q, k, v = _qkv()
    mask = jnp.ones((2, 256), bool).at[:, -50:].set(False)
    cfg = _cfg()
    params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    out = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out[:, -50:]), 0.0, atol=1e-7)


def test_padding_invariance_of_valid_outputs():
    """Changing values at PADDED positions must not change valid outputs."""
    q, k, v = _qkv()
    mask = jnp.ones((2, 256), bool).at[:, -64:].set(False)
    cfg = _cfg()
    params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    out1 = bsa_attention(params, q, k, v, cfg=cfg, mask=mask)
    q2 = q.at[:, -64:].add(100.0)
    k2 = k.at[:, -64:].add(-50.0)
    v2 = v.at[:, -64:].add(9.0)
    out2 = bsa_attention(params, q2, k2, v2, cfg=cfg, mask=mask)
    np.testing.assert_allclose(np.asarray(out1[:, :192]), np.asarray(out2[:, :192]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# causal properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(t_perturb=st.integers(128, 255))
def test_causality_no_future_leak(t_perturb):
    q, k, v = _qkv()
    cfg = _cfg(query_cmp_selection=False, group_size=0)
    params = nsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    o1 = nsa_causal_attention(params, q, k, v, cfg=cfg)
    q2 = q.at[:, t_perturb].add(3.0)
    k2 = k.at[:, t_perturb].add(3.0)
    v2 = v.at[:, t_perturb].add(3.0)
    o2 = nsa_causal_attention(params, q2, k2, v2, cfg=cfg)
    # positions strictly before any influence boundary are unchanged; the
    # compression/selection branches quantise to ℓ-blocks, so the safe
    # prefix ends at the start of the block containing t_perturb
    safe = (t_perturb // cfg.cmp_block) * cfg.cmp_block
    safe = min(safe, (t_perturb // cfg.effective_local_window)
               * cfg.effective_local_window)
    err = float(jnp.abs(o1 - o2)[:, :safe].max())
    assert err == 0.0, f"future leak at prefix<{safe}: {err}"


def test_decode_equals_train_bitwise_tolerance():
    B, N, Hq, Hkv, D = 1, 128, 4, 2, 16
    cfg = _cfg(query_cmp_selection=False, group_size=0, top_k=2)
    params = nsa_init(KEY, cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D, d_model=64)
    q, k, v = _qkv(B, N, Hq, Hkv, D)
    train = nsa_causal_attention(params, q, k, v, cfg=cfg)
    cache = init_decode_cache(B, N, Hkv, D, cfg, dtype=jnp.float32)
    step = jax.jit(lambda p, a, b, c, cc: nsa_causal_decode(p, a, b, c, cc, cfg=cfg))
    outs = []
    for t in range(N):
        o, cache = step(params, q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], cache)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(train), atol=2e-5)


# ---------------------------------------------------------------------------
# variants & receptive field
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", [
    dict(),                                                 # paper BSA
    dict(group_size=0, query_cmp_selection=False),          # w/o group selection
    dict(group_compression=True, phi="mlp"),                # w/ group compression
    dict(gate_mode="token"),
    dict(jnp_chunk_tokens=64),
])
def test_all_variants_finite_and_shaped(variant):
    q, k, v = _qkv()
    cfg = _cfg(**variant)
    params = bsa_init(KEY, cfg, n_heads=4, n_kv_heads=2, head_dim=16, d_model=64)
    x = jax.random.normal(KEY, (2, 256, 64))
    out = bsa_attention(params, q, k, v, cfg=cfg, x=x)
    assert out.shape == q.shape and bool(jnp.isfinite(out).all())


def test_receptive_field_grows_with_branches():
    """Paper Fig. 2: ball-only < ball+selection < ball+selection+compression.
    Measured as the number of value positions influencing query 0's output."""
    B, N, Hq, Hkv, D = 1, 256, 2, 2, 16
    q, k, v = _qkv(B, N, Hq, Hkv, D)
    cfg = _cfg(top_k=2)
    params = bsa_init(KEY, cfg, n_heads=Hq, n_kv_heads=Hkv, head_dim=D, d_model=32)

    def influence(branch):
        def f(vv):
            out, aux = bsa_attention(params, q, k, vv, cfg=cfg, return_aux=True)
            return jnp.sum(aux[branch][0, 0] ** 2)
        g = jax.grad(f)(v)
        return int((jnp.abs(g[0]).sum(axis=(1, 2)) > 1e-9).sum())

    r_ball = influence("ball")
    r_slc = influence("slc")
    r_cmp = influence("cmp")
    assert r_ball <= cfg.ball_size
    assert r_cmp == N                     # compression sees every block
    assert r_slc <= cfg.top_k * cfg.slc_block * (N // 8)  # sane bound


def test_full_attention_oracle_consistency():
    """BSA with ball = whole sequence and all blocks selected ≈ full attn mix."""
    q, k, v = _qkv(1, 64, 2, 2, 16)
    out = full_attention(q, k, v)
    # plain softmax reference
    logits = jnp.einsum("bnhd,bmhd->bhnm", q, k) / 4.0
    want = jnp.einsum("bhnm,bmhd->bnhd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
