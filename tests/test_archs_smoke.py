"""Per-architecture smoke tests: reduced config, one forward + one grad step
on CPU, asserting output shapes and finiteness.  Decode-capable archs also
run two decode steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.reduce import SMOKE_SEQ, smoke_config
from repro.models.api import model_api

POINT_ARCHS = ["shapenet-bsa", "shapenet-bsa-no-group", "shapenet-bsa-group-cmp",
               "shapenet-full", "shapenet-erwin"]


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCH_IDS + POINT_ARCHS)
def test_arch_smoke_train(arch):
    mcfg = smoke_config(get_config(arch))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = api.make_batch(rng, 2, SMOKE_SEQ)

    (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert _finite(grads), f"{arch}: non-finite grads"

    out = api.forward(params, batch)
    assert not bool(jnp.isnan(out).any()), f"{arch}: NaN in forward"
    if mcfg.family == "pointcloud":
        assert out.shape == (2, SMOKE_SEQ, mcfg.out_dim)
    elif mcfg.family == "audio":
        assert out.shape[-1] == mcfg.vocab_size
    elif mcfg.family == "vlm":
        assert out.shape == (2, SMOKE_SEQ, mcfg.vocab_size)
    else:
        assert out.shape == (2, SMOKE_SEQ, mcfg.vocab_size)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b", "qwen2-moe-a2.7b"])
def test_arch_smoke_decode(arch):
    mcfg = smoke_config(get_config(arch))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    caches = api.cache_init(2, SMOKE_SEQ, jnp.float32)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(2):
        logits, caches = api.decode_step(params, tok, caches)
        assert logits.shape == (2, mcfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        tok = logits.argmax(-1).astype(jnp.int32)


def test_seamless_decode():
    mcfg = smoke_config(get_config("seamless-m4t-medium"))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = api.make_batch(rng, 2, SMOKE_SEQ)
    from repro.models.encdec import encode
    memory = encode(params, batch["frames"], mcfg=mcfg)
    caches = api.cache_init(2, SMOKE_SEQ, jnp.float32, params=params, memory=memory)
    logits, caches = api.decode_step(params, jnp.array([1, 2], jnp.int32), caches)
    assert logits.shape == (2, mcfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_full_configs_have_exact_dims():
    """Assigned-architecture dims must match the assignment table verbatim."""
    expect = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for arch, (L, d, h, kv, ff, V) in expect.items():
        m = get_config(arch)
        assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
                m.vocab_size) == (L, d, h, kv, ff, V), arch
    assert get_config("qwen2-moe-a2.7b").n_experts == 60
    assert get_config("qwen2-moe-a2.7b").experts_per_token == 4
    assert get_config("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert get_config("jamba-1.5-large-398b").attn_period == 8
    assert get_config("mamba2-1.3b").ssm_state == 128
