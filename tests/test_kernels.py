"""Per-kernel allclose tests vs pure-jnp oracles, swept over shapes/dtypes.

Kernels execute under interpret=True on CPU (the container has no TPU);
the kernel bodies are identical to what runs on hardware.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,N,H,D,m", [
    (1, 256, 2, 64, 64),
    (2, 512, 4, 32, 128),
    (1, 256, 1, 128, 256),
    (2, 128, 2, 64, 32),
])
def test_ball_attention(B, N, H, D, m, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, N, H, D), dtype)
    k = _rand(k2, (B, N, H, D), dtype)
    v = _rand(k3, (B, N, H, D), dtype)
    mask = jnp.ones((B, N), bool).at[:, -N // 8:].set(False)
    out = ops.ball_attention(q, k, v, mask, m)
    want = ref.ball_attention_ref(q, k, v, mask, m)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,N,H,D,w", [
    (1, 256, 2, 64, 64),
    (2, 512, 2, 32, 128),
    (1, 128, 4, 128, 32),
])
def test_local_window(B, N, H, D, w, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, N, H, D), dtype)
    k = _rand(k2, (B, N, H, D), dtype)
    v = _rand(k3, (B, N, H, D), dtype)
    out = ops.local_window_attention(q, k, v, w)
    want = ref.local_window_attention_ref(q, k, v, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["plain", "causal", "block_causal", "key_valid"])
@pytest.mark.parametrize("B,N,L,H,D", [
    (1, 256, 256, 2, 64),
    (2, 512, 64, 2, 64),     # skinny KV (compression-branch shape)
    (1, 384, 48, 1, 128),    # non-power-of-two tiles
])
def test_flash(B, N, L, H, D, mode, dtype):
    if mode == "causal" and L != N:
        pytest.skip("token-causal assumes aligned q/k")
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, N, H, D), dtype)
    k = _rand(k2, (B, L, H, D), dtype)
    v = _rand(k3, (B, L, H, D), dtype)
    kwargs = {}
    if mode == "causal":
        kwargs = dict(causal=True)
    elif mode == "block_causal":
        kwargs = dict(block_causal=True, ell=N // L)
    elif mode == "key_valid":
        kwargs = dict(key_valid=jnp.ones((B, L), bool).at[:, -L // 4:].set(False))
    out = ops.flash_attention(q, k, v, **kwargs)
    want = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,N,Hq,Hkv,D,ell,g,ks", [
    (1, 256, 2, 1, 64, 8, 8, 4),
    (2, 512, 4, 2, 64, 8, 16, 4),
    (1, 256, 4, 4, 32, 16, 16, 2),   # MHA, bigger blocks
    (1, 128, 8, 2, 64, 8, 8, 6),     # high GQA rep
])
def test_selection(B, N, Hq, Hkv, D, ell, g, ks, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(KEY, 5)
    q = _rand(k1, (B, N, Hq, D), dtype)
    k = _rand(k2, (B, N, Hkv, D), dtype)
    v = _rand(k3, (B, N, Hkv, D), dtype)
    G, nb = N // g, N // ell
    idx = jax.random.randint(k4, (B, G, Hkv, ks), 0, nb)
    valid = jax.random.bernoulli(k5, 0.85, (B, G, Hkv, ks))
    mask = jnp.ones((B, N), bool).at[:, -N // 8:].set(False)
    out = ops.selection_attention(q, k, v, idx, valid, mask, block_size=ell, group_size=g)
    want = ref.selection_attention_ref(q, k, v, idx, valid, mask, block_size=ell, group_size=g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_selection_all_invalid_group_is_zero():
    B, N, Hq, Hkv, D, ell, g, ks = 1, 128, 2, 1, 32, 8, 8, 4
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, N, Hq, D), jnp.float32)
    k = _rand(k2, (B, N, Hkv, D), jnp.float32)
    v = _rand(k3, (B, N, Hkv, D), jnp.float32)
    idx = jnp.zeros((B, N // g, Hkv, ks), jnp.int32)
    valid = jnp.zeros((B, N // g, Hkv, ks), bool).at[:, 1:].set(True)
    out = ops.selection_attention(q, k, v, idx, valid, None, block_size=ell, group_size=g)
    assert not bool(jnp.isnan(out).any())
    np.testing.assert_allclose(np.asarray(out[:, :g]), 0.0, atol=1e-6)


def test_flash_matches_full_attention_einsum():
    """flash kernel == plain softmax attention (independent oracle)."""
    B, N, H, D = 1, 256, 2, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q, k, v = (_rand(kk, (B, N, H, D), jnp.float32) for kk in (k1, k2, k3))
    out = ops.flash_attention(q, k, v, causal=True)
    logits = jnp.einsum("bnhd,bmhd->bhnm", q, k) / (D ** 0.5)
    mask = jnp.tril(jnp.ones((N, N), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    want = jnp.einsum("bhnm,bmhd->bnhd", jax.nn.softmax(logits, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
