"""Gradient tests: jax.grad through each Pallas kernel vs the jnp reference.

The kernel path carries fused custom_vjp backward passes (FlashAttention-style
recomputation from logsumexp residuals); these tests assert that dQ/dK/dV —
and, end-to-end, parameter gradients of ``bsa_attention`` /
``nsa_causal_attention`` on the ``"pallas"`` backend — match the ``"jnp"``
reference backend to atol 1e-3.  Kernels run under interpret mode on CPU.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSAConfig, bsa_attention, bsa_init,
                        nsa_causal_attention, nsa_init)
from repro.core.branches import repeat_kv
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(123)
TOL = dict(atol=1e-3, rtol=1e-3)


@pytest.fixture(autouse=True)
def _no_env_override(monkeypatch):
    """These tests compare NAMED backends (pallas vs jnp); a CI matrix leg
    pinning REPRO_ATTENTION_BACKEND would collapse both sides to one backend
    and make the parity assertions vacuous."""
    monkeypatch.delenv("REPRO_ATTENTION_BACKEND", raising=False)


def _assert_grads_close(got, want):
    gl, wl = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(gl) == len(wl)
    for g, w in zip(gl, wl):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **TOL)


def _qkvw(B, N, Hq, Hkv, D, L=None):
    L = N if L is None else L
    ks = jax.random.split(KEY, 4)
    return (jax.random.normal(ks[0], (B, N, Hq, D)),
            jax.random.normal(ks[1], (B, L, Hkv, D)),
            jax.random.normal(ks[2], (B, L, Hkv, D)),
            jax.random.normal(ks[3], (B, N, Hq, D)))


def _mask(B, N, masked):
    if not masked:
        return None
    return jnp.ones((B, N), bool).at[:, -N // 8:].set(False)


@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("rep", [1, 4])
def test_ball_attention_grads(masked, rep):
    B, N, Hkv, D, m = 1, 128, 1, 32, 32
    q, k, v, w = _qkvw(B, N, Hkv * rep, Hkv, D)
    mask = _mask(B, N, masked)

    def loss(fn):
        def f(q, k, v):
            return jnp.sum(fn(q, repeat_kv(k, rep), repeat_kv(v, rep), mask, m) * w)
        return f

    got = jax.grad(loss(ops.ball_attention), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(ref.ball_attention_ref), argnums=(0, 1, 2))(q, k, v)
    _assert_grads_close(got, want)


@pytest.mark.parametrize("rep", [1, 4])
def test_local_window_grads(rep):
    B, N, Hkv, D, w_blk = 1, 128, 1, 32, 32
    q, k, v, w = _qkvw(B, N, Hkv * rep, Hkv, D)

    def loss(fn):
        def f(q, k, v):
            return jnp.sum(fn(q, repeat_kv(k, rep), repeat_kv(v, rep), w_blk) * w)
        return f

    got = jax.grad(loss(ops.local_window_attention), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(ref.local_window_attention_ref), argnums=(0, 1, 2))(q, k, v)
    _assert_grads_close(got, want)


@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("rep", [1, 4])
def test_flash_grads(masked, rep):
    B, N, L, Hkv, D = 1, 128, 128, 1, 32
    q, k, v, w = _qkvw(B, N, Hkv * rep, Hkv, D, L=L)
    kwargs = dict(key_valid=_mask(B, L, True)) if masked else {}

    def loss(fn):
        def f(q, k, v):
            return jnp.sum(fn(q, repeat_kv(k, rep), repeat_kv(v, rep), **kwargs) * w)
        return f

    got = jax.grad(loss(ops.flash_attention), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(ref.flash_attention_ref), argnums=(0, 1, 2))(q, k, v)
    _assert_grads_close(got, want)


@pytest.mark.parametrize("mode", ["causal", "block_causal"])
def test_flash_causal_grads(mode):
    B, N, Hq, D = 1, 128, 2, 32
    if mode == "causal":
        L, kwargs = N, dict(causal=True)
    else:
        L, kwargs = 16, dict(block_causal=True, ell=N // 16)
    q, k, v, w = _qkvw(B, N, Hq, Hq, D, L=L)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v, **kwargs) * w)

    got = jax.grad(loss(ops.flash_attention), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(ref.flash_attention_ref), argnums=(0, 1, 2))(q, k, v)
    _assert_grads_close(got, want)


@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("rep", [1, 4])
def test_selection_grads(masked, rep):
    B, N, Hkv, D, ell, g, ks = 1, 128, 2, 32, 8, 8, 4
    q, k, v, w = _qkvw(B, N, Hkv * rep, Hkv, D)
    G, nb = N // g, N // ell
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, rep))
    idx = jax.random.randint(k1, (B, G, Hkv, ks), 0, nb)
    valid = jax.random.bernoulli(k2, 0.85, (B, G, Hkv, ks))
    mask = _mask(B, N, masked)

    def loss(fn):
        def f(q, k, v):
            return jnp.sum(fn(q, k, v, idx, valid, mask,
                              block_size=ell, group_size=g) * w)
        return f

    got = jax.grad(loss(ops.selection_attention), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(ref.selection_attention_ref), argnums=(0, 1, 2))(q, k, v)
    _assert_grads_close(got, want)


# ---------------------------------------------------------------------------
# End-to-end: jax.grad of the full attention stacks, kernels vs jnp reference
# ---------------------------------------------------------------------------

_E2E_CFG = dict(ball_size=32, local_window=32, cmp_block=8, slc_block=8,
                top_k=2, group_size=8)


@pytest.mark.parametrize("masked", [False, True])
def test_bsa_attention_grads_kernel_path(masked):
    B, N, Hq, Hkv, D, dm = 1, 128, 4, 2, 32, 64
    q, k, v, w = _qkvw(B, N, Hq, Hkv, D)
    mask = _mask(B, N, masked)
    cfg = BSAConfig(**_E2E_CFG)
    params = bsa_init(jax.random.fold_in(KEY, 7), cfg, n_heads=Hq,
                      n_kv_heads=Hkv, head_dim=D, d_model=dm)

    def loss(backend):
        c = dataclasses.replace(cfg, backend=backend)

        def f(params, q, k, v):
            return jnp.sum(bsa_attention(params, q, k, v, cfg=c, mask=mask) * w)
        return f

    got = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3))(params, q, k, v)
    want = jax.grad(loss("jnp"), argnums=(0, 1, 2, 3))(params, q, k, v)
    _assert_grads_close(got, want)


def test_nsa_causal_attention_grads_kernel_path():
    B, N, Hq, Hkv, D, dm = 1, 128, 4, 2, 32, 64
    q, k, v, w = _qkvw(B, N, Hq, Hkv, D)
    cfg = BSAConfig(**_E2E_CFG)
    params = nsa_init(jax.random.fold_in(KEY, 8), cfg, n_heads=Hq,
                      n_kv_heads=Hkv, head_dim=D, d_model=dm)

    def loss(backend):
        c = dataclasses.replace(cfg, backend=backend)

        def f(params, q, k, v):
            return jnp.sum(nsa_causal_attention(params, q, k, v, cfg=c) * w)
        return f

    got = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3))(params, q, k, v)
    want = jax.grad(loss("jnp"), argnums=(0, 1, 2, 3))(params, q, k, v)
    _assert_grads_close(got, want)


@pytest.mark.parametrize("kernel", ["selection", "local"])
def test_grads_finite_under_logit_blowup(kernel):
    """Regression: clamped fetches (invalid selection / last local block) must
    be masked in LOGIT space in the backward — exp-then-zero gives inf·0=NaN
    once a clamped logit exceeds the row's lse (large-magnitude q/k, as in
    attention-logit blowup during training)."""
    B, N, Hkv, D = 1, 64, 1, 32
    q, k, v, w = _qkvw(B, N, Hkv, Hkv, D)
    q, k = q * 30, k * 30
    if kernel == "selection":
        ell, g, ks = 8, 8, 4
        G, nb = N // g, N // ell
        k1, k2 = jax.random.split(KEY)
        idx = jax.random.randint(k1, (B, G, Hkv, ks), 0, nb)
        valid = jax.random.bernoulli(k2, 0.5, (B, G, Hkv, ks))

        def kfn(q, k, v):
            return jnp.sum(ops.selection_attention(
                q, k, v, idx, valid, None, block_size=ell, group_size=g) * w)

        def rfn(q, k, v):
            return jnp.sum(ref.selection_attention_ref(
                q, k, v, idx, valid, None, block_size=ell, group_size=g) * w)
    else:
        def kfn(q, k, v):
            return jnp.sum(ops.local_window_attention(q, k, v, 32) * w)

        def rfn(q, k, v):
            return jnp.sum(ref.local_window_attention_ref(q, k, v, 32) * w)

    got = jax.grad(kfn, argnums=(0, 1, 2))(q, k, v)
    assert all(bool(jnp.isfinite(g).all()) for g in got)
    _assert_grads_close(got, jax.grad(rfn, argnums=(0, 1, 2))(q, k, v))


# ---------------------------------------------------------------------------
# Tiered-tolerance dtype sweep: the precision contract (bf16 matmul operands,
# fp32 accumulation) across every kernel, kernel-vs-oracle grads.  fp32 keeps
# the strict 1e-3 tolerance; bf16 tolerances are widened PER KERNEL — bf16 has
# ~3 decimal digits, and error compounds with the number of chained matmuls
# (selection re-gathers, local merges two softmax halves).
# ---------------------------------------------------------------------------

_DTYPE_TOL = {
    "float32": {k: dict(atol=1e-3, rtol=1e-3)
                for k in ("ball", "local", "flash", "selection")},
    "bfloat16": {"ball": dict(atol=4e-2, rtol=4e-2),
                 "local": dict(atol=4e-2, rtol=4e-2),
                 "flash": dict(atol=4e-2, rtol=4e-2),
                 "selection": dict(atol=6e-2, rtol=6e-2)},
}


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("kernel", ["ball", "local", "flash", "selection"])
def test_grad_parity_dtype_sweep(kernel, dtype):
    tol = _DTYPE_TOL[dtype][kernel]
    B, N, Hkv, D = 1, 128, 2, 32
    rep = 2
    q, k, v, w = _qkvw(B, N, Hkv * rep, Hkv, D)
    dt = jnp.dtype(dtype)
    q, k, v = q.astype(dt), k.astype(dt), v.astype(dt)
    mask = _mask(B, N, True)

    if kernel == "ball":
        kfn = lambda q, k, v: ops.ball_attention(q, k, v, mask, 32)
        rfn = lambda q, k, v: ref.ball_attention_ref(
            q, repeat_kv(k, rep), repeat_kv(v, rep), mask, 32)
    elif kernel == "local":
        kfn = lambda q, k, v: ops.local_window_attention(q, k, v, 32, mask)
        rfn = lambda q, k, v: ref.local_window_attention_ref(
            q, repeat_kv(k, rep), repeat_kv(v, rep), 32, mask)
    elif kernel == "flash":
        kfn = lambda q, k, v: ops.flash_attention(q, k, v, key_valid=mask)
        rfn = lambda q, k, v: ref.flash_attention_ref(
            q, repeat_kv(k, rep), repeat_kv(v, rep), key_valid=mask)
    else:
        ell, g, ks = 8, 8, 4
        G, nb = N // g, N // ell
        k1, k2 = jax.random.split(jax.random.fold_in(KEY, 21))
        idx = jax.random.randint(k1, (B, G, Hkv, ks), 0, nb)
        valid = jax.random.bernoulli(k2, 0.85, (B, G, Hkv, ks))
        kfn = lambda q, k, v: ops.selection_attention(
            q, k, v, idx, valid, mask, block_size=ell, group_size=g)
        rfn = lambda q, k, v: ref.selection_attention_ref(
            q, k, v, idx, valid, mask, block_size=ell, group_size=g)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v).astype(jnp.float32)
                                       * w)

    got = jax.grad(loss(kfn), argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(rfn), argnums=(0, 1, 2))(q, k, v)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32), **tol)


def test_kernel_train_step_is_jittable():
    """A jitted fwd+bwd step on the kernel path compiles and yields finite grads."""
    B, N, Hq, Hkv, D, dm = 1, 128, 4, 2, 32, 64
    q, k, v, w = _qkvw(B, N, Hq, Hkv, D)
    cfg = BSAConfig(backend="pallas", **_E2E_CFG)
    params = bsa_init(jax.random.fold_in(KEY, 9), cfg, n_heads=Hq,
                      n_kv_heads=Hkv, head_dim=D, d_model=dm)

    @jax.jit
    def step(params, q, k, v):
        def f(p):
            return jnp.sum(bsa_attention(p, q, k, v, cfg=cfg) * w)
        return jax.value_and_grad(f)(params)

    loss, grads = step(params, q, k, v)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
