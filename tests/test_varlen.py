"""Packed-varlen (offsets-based) layout: packing, kernels, BSA, serving.

The load-bearing invariant mirrors tests/test_batching.py one level deeper:
a PACKED batch of mixed-size clouds — samples concatenated on one unbatched
axis with an ``offsets`` boundary array (docs/varlen.md) — equals running
every cloud alone AND equals the bucket-padded layout, forward and
gradients, on the jnp oracle and the Pallas kernel paths.  Nothing may leak
across a sample boundary on the packed axis.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BSAConfig,
    bsa_attention,
    bsa_attention_varlen,
    bsa_init,
    pack_ragged,
    pack_varlen,
    unpack_varlen,
    use_backend,
)
from repro.numerics import segment_ids_from_offsets

KEY = jax.random.PRNGKey(23)

# adversarial size mixes: prime-ish lengths, a singleton cloud, and a
# max-variance batch (largest next to smallest)
MIXES = [
    (20, 45, 33, 11),
    (64, 1, 37),
    (128, 16),
]


@pytest.fixture(autouse=True)
def _no_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_ATTENTION_BACKEND", raising=False)


def _cfg(**kw):
    base = dict(ball_size=16, local_window=16, cmp_block=8, slc_block=8,
                top_k=2, group_size=8)
    base.update(kw)
    return BSAConfig(**base)


def _clouds(sizes, Hq=4, Hkv=2, D=16, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda n, h: rng.standard_normal((n, h, D)).astype(np.float32)
    return ([mk(n, Hq) for n in sizes], [mk(n, Hkv) for n in sizes],
            [mk(n, Hkv) for n in sizes])


def _pack(qs, ks, vs, multiple, **kw):
    qp, offs, mask = pack_varlen(qs, multiple, **kw)
    kp, _, _ = pack_varlen(ks, multiple, **kw)
    vp, _, _ = pack_varlen(vs, multiple, **kw)
    return (jnp.asarray(qp), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(offs), jnp.asarray(mask))


# ---------------------------------------------------------------------------
# host-side packing helpers
# ---------------------------------------------------------------------------

def test_pack_varlen_roundtrip():
    rng = np.random.default_rng(1)
    arrays = [rng.standard_normal((n, 5)).astype(np.float32) for n in (7, 30, 16)]
    packed, offsets, mask = pack_varlen(arrays, 16)
    # per-sample ball padding: 16 + 32 + 16 = 64 packed rows, capacity ≥ that
    assert offsets.tolist() == [0, 16, 48, 64]
    assert packed.shape[0] >= 64 and packed.shape[0] % 16 == 0
    assert mask.sum() == 7 + 30 + 16
    back = unpack_varlen(packed, offsets, mask)
    for a, b in zip(arrays, back):
        np.testing.assert_array_equal(a, b)
    # padding rows (within-sample and capacity tail) are the fill value
    assert np.all(packed[7:16] == 0.0) and np.all(packed[64:] == 0.0)


def test_pack_varlen_static_shapes():
    a = [np.zeros((20, 2), np.float32)]
    # max_samples pads offsets with trailing repeats (empty segments)
    packed, offsets, mask = pack_varlen(a, 16, max_samples=3)
    assert offsets.tolist() == [0, 32, 32, 32]
    back = unpack_varlen(packed, offsets, mask)
    assert [b.shape[0] for b in back] == [20, 0, 0]
    # pad_to freezes the capacity; must be a multiple and hold the total
    packed, _, _ = pack_varlen(a, 16, pad_to=64)
    assert packed.shape[0] == 64
    with pytest.raises(ValueError):
        pack_varlen(a, 16, pad_to=16)
    with pytest.raises(ValueError):
        pack_varlen(a, 16, pad_to=50)
    with pytest.raises(ValueError):
        pack_varlen(a * 4, 16, max_samples=3)


def test_segment_ids_from_offsets():
    offs = jnp.asarray([0, 16, 48, 64, 64], jnp.int32)   # trailing empty seg
    seg = segment_ids_from_offsets(offs, 80)
    assert seg.shape == (80,)
    assert int(seg[0]) == 0 and int(seg[15]) == 0
    assert int(seg[16]) == 1 and int(seg[47]) == 1
    assert int(seg[48]) == 2 and int(seg[63]) == 2
    # capacity tail gets an id strictly greater than every real segment,
    # and the empty segment (3) owns no positions
    assert np.all(np.asarray(seg[64:]) == 4)


# ---------------------------------------------------------------------------
# kernel wrappers vs the jnp oracle (fwd + grads)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", MIXES)
def test_flash_varlen_kernel_matches_oracle(sizes):
    from repro.core.backend import get_backend
    from repro.kernels import ops
    qs, ks, vs = _clouds(sizes)
    q, k, v, offs, mask = _pack(qs, ks, vs, 16)
    oracle = get_backend("jnp").flash_varlen

    def make_loss(fn):
        def loss(q, k, v):
            o = fn(q, k, v, offs, offs, key_valid=mask)
            return jnp.sum(jnp.where(mask[:, None, None], o, 0.0) ** 2)
        return loss

    out = ops.flash_attention_varlen(q, k, v, offs, offs, key_valid=mask)
    want = oracle(q, k, v, offs, offs, key_valid=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    g_k = jax.grad(make_loss(ops.flash_attention_varlen),
                   argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(make_loss(oracle), argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g_k, g_r, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3, err_msg=nm)
    # a masked (padding) key row gets exactly zero gradient
    pad_rows = ~np.asarray(mask)
    np.testing.assert_allclose(np.asarray(g_k[1])[pad_rows], 0.0, atol=1e-7)


def test_flash_varlen_no_cross_sample_leak():
    """Perturbing sample j must not change sample i ≠ j (kernel path)."""
    from repro.kernels import ops
    sizes = (32, 48)
    qs, ks, vs = _clouds(sizes)
    q, k, v, offs, mask = _pack(qs, ks, vs, 16)
    out = ops.flash_attention_varlen(q, k, v, offs, offs, key_valid=mask)
    k2 = k.at[int(offs[1]):].add(7.0)          # clobber sample 1's keys
    v2 = v.at[int(offs[1]):].add(-3.0)
    out2 = ops.flash_attention_varlen(q, k2, v2, offs, offs, key_valid=mask)
    np.testing.assert_array_equal(np.asarray(out[:sizes[0]]),
                                  np.asarray(out2[:sizes[0]]))
    assert np.abs(np.asarray(out2[int(offs[1]):int(offs[1]) + sizes[1]]
                             - out[int(offs[1]):int(offs[1]) + sizes[1]])).max() > 1e-3


@pytest.mark.parametrize("sizes", MIXES)
def test_local_varlen_kernel_matches_oracle(sizes):
    from repro.core.backend import get_backend
    from repro.kernels import ops
    w = 16
    qs, ks, vs = _clouds(sizes)
    q, k, v, offs, mask = _pack(qs, ks, vs, w)
    oracle = get_backend("jnp").local_window_varlen

    def make_loss(fn):
        def loss(q, k, v):
            o = fn(q, k, v, offs, window=w, mask=mask)
            return jnp.sum(jnp.where(mask[:, None, None], o, 0.0) ** 2)
        return loss

    out = ops.local_window_attention_varlen(q, k, v, offs, w, mask=mask)
    want = oracle(q, k, v, offs, window=w, mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    g_k = jax.grad(make_loss(ops.local_window_attention_varlen),
                   argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(make_loss(oracle), argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g_k, g_r, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3, err_msg=nm)


def test_local_varlen_window_does_not_cross_boundary():
    """First block of a segment must NOT see the previous segment's last
    block (which is adjacent on the packed axis)."""
    from repro.kernels import ops
    w = 16
    sizes = (16, 16)
    qs, ks, vs = _clouds(sizes, seed=5)
    q, k, v, offs, mask = _pack(qs, ks, vs, w)
    out = ops.local_window_attention_varlen(q, k, v, offs, w, mask=mask)
    k2 = k.at[:16].add(9.0)                    # clobber sample 0 entirely
    v2 = v.at[:16].add(9.0)
    out2 = ops.local_window_attention_varlen(q, k2, v2, offs, w, mask=mask)
    np.testing.assert_array_equal(np.asarray(out[16:32]),
                                  np.asarray(out2[16:32]))


# ---------------------------------------------------------------------------
# full BSA: packed == per-sample == bucket-padded (fwd + grads)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas", "interpret"])
@pytest.mark.parametrize("sizes", MIXES)
def test_bsa_varlen_equals_per_sample(backend, sizes):
    cfg = _cfg(backend=backend)
    qs, ks, vs = _clouds(sizes)
    q, k, v, offs, mask = _pack(qs, ks, vs, cfg.ball_size)
    params = bsa_init(jax.random.fold_in(KEY, 1), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    atol = 1e-5 if backend == "jnp" else 1e-3

    out_p = bsa_attention_varlen(params, q, k, v, cfg=cfg, offsets=offs,
                                 mask=mask)
    for i, n in enumerate(sizes):
        q1, m1 = pack_ragged([qs[i]], cfg.ball_size, geometric=False)
        k1, _ = pack_ragged([ks[i]], cfg.ball_size, geometric=False)
        v1, _ = pack_ragged([vs[i]], cfg.ball_size, geometric=False)
        solo = bsa_attention(params, jnp.asarray(q1), jnp.asarray(k1),
                             jnp.asarray(v1), cfg=cfg, mask=jnp.asarray(m1))
        a = int(offs[i])
        np.testing.assert_allclose(np.asarray(out_p[a:a + n]),
                                   np.asarray(solo[0][:n]),
                                   atol=atol, rtol=atol,
                                   err_msg=f"fwd sample {i} (n={n})")
    # padded rows (within-sample and capacity tail) are exactly zero
    np.testing.assert_allclose(
        np.asarray(out_p)[~np.asarray(mask)], 0.0, atol=1e-7)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bsa_varlen_equals_bucket_padded_with_grads(backend):
    """Packed-varlen vs the padded-bucket layout of the SAME mixed batch:
    forward, loss, and all gradients agree."""
    sizes = (64, 40, 24)
    N = 64
    cfg = _cfg(backend=backend)
    qs, ks, vs = _clouds(sizes)
    params = bsa_init(jax.random.fold_in(KEY, 2), cfg, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_model=64)
    atol = 1e-5 if backend == "jnp" else 1e-3

    # padded-bucket layout
    qb, maskb = pack_ragged(qs, cfg.ball_size, pad_to=N)
    kb, _ = pack_ragged(ks, cfg.ball_size, pad_to=N)
    vb, _ = pack_ragged(vs, cfg.ball_size, pad_to=N)
    qb, kb, vb, maskb = map(jnp.asarray, (qb, kb, vb, maskb))

    def loss_pad(p, q, k, v, m):
        return jnp.sum(bsa_attention(p, q, k, v, cfg=cfg, mask=m) ** 2)

    # packed-varlen layout
    qp, kp, vp, offs, maskp = _pack(qs, ks, vs, cfg.ball_size)

    def loss_pk(p, q, k, v, m):
        return jnp.sum(bsa_attention_varlen(p, q, k, v, cfg=cfg, offsets=offs,
                                            mask=m) ** 2)

    l_pad, g_pad = jax.value_and_grad(loss_pad)(params, qb, kb, vb, maskb)
    l_pk, g_pk = jax.value_and_grad(loss_pk)(params, qp, kp, vp, maskp)
    np.testing.assert_allclose(float(l_pk), float(l_pad), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_pk), jax.tree.leaves(g_pad)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=atol, rtol=1e-3)
    # input grads agree per sample (packed rows vs padded slots)
    gq_pk, gk_pk = jax.grad(loss_pk, argnums=(1, 2))(params, qp, kp, vp, maskp)
    gq_pad, gk_pad = jax.grad(loss_pad, argnums=(1, 2))(params, qb, kb, vb,
                                                        maskb)
    for i, n in enumerate(sizes):
        a = int(offs[i])
        np.testing.assert_allclose(np.asarray(gq_pk[a:a + n]),
                                   np.asarray(gq_pad[i, :n]),
                                   atol=atol, rtol=1e-3, err_msg=f"dq {i}")
        np.testing.assert_allclose(np.asarray(gk_pk[a:a + n]),
                                   np.asarray(gk_pad[i, :n]),
                                   atol=atol, rtol=1e-3, err_msg=f"dk {i}")


def test_bsa_varlen_backend_fallback():
    """A plug-in backend WITHOUT varlen ops serves packed batches through
    the jnp oracle via get_varlen (same fallback contract as get_combine)."""
    from repro.core.backend import JnpBackend, get_varlen

    class Minimal:
        name = "minimal"
        ball = JnpBackend.ball
        flash = JnpBackend.flash
        local_window = JnpBackend.local_window
        selection = JnpBackend.selection

    fn = get_varlen(Minimal(), "flash")
    assert fn.__self__.name == "jnp"           # bound to the jnp oracle
    assert callable(get_varlen(Minimal(), "ball"))


# ---------------------------------------------------------------------------
# model + serving integration
# ---------------------------------------------------------------------------

def test_geometry_engine_packed_matches_padded():
    import dataclasses

    from repro.configs import get_config
    from repro.models.api import model_api
    from repro.serving import GeometryEngine

    mcfg = get_config("shapenet-bsa").scaled(
        n_layers=2, d_model=32, n_heads=2, head_dim=16, n_kv_heads=2, d_ff=64)
    mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, ball_size=16,
                                               local_window=16))
    api = model_api(mcfg)
    params = api.init(jax.random.PRNGKey(0))
    eng_pk = GeometryEngine(api, params, batch_slots=3)
    assert eng_pk.layout == "packed"           # auto default for BSA
    eng_pad = GeometryEngine(api, params, batch_slots=3, layout="padded")

    rng = np.random.default_rng(7)
    clouds = []
    for n in (20, 45, 33, 11):                 # short final batch too
        pts = rng.standard_normal((n, 3)).astype(np.float32)
        feats = rng.standard_normal((n, mcfg.in_dim)).astype(np.float32)
        clouds.append((pts, feats))

    out_pk = eng_pk.predict(clouds)
    out_pad = eng_pad.predict(clouds)
    assert [o.shape for o in out_pk] == [(20, 1), (45, 1), (33, 1), (11, 1)]
    for a, b in zip(out_pk, out_pad):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_pc_model_offsets_path_matches_padded():
    """pc_apply with a packed row + offsets == bucket-padded rows."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.pointcloud import pc_apply, pc_init

    mcfg = get_config("shapenet-bsa").scaled(
        n_layers=2, d_model=32, n_heads=2, head_dim=16, n_kv_heads=2, d_ff=64)
    mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, ball_size=16,
                                               local_window=16))
    params = pc_init(jax.random.PRNGKey(1), mcfg)
    rng = np.random.default_rng(9)
    sizes = (40, 17)
    feats = [rng.standard_normal((n, mcfg.in_dim)).astype(np.float32)
             for n in sizes]

    packed, offs, maskp = pack_varlen(feats, 16)
    with use_backend("jnp"):
        out_pk = pc_apply(params, jnp.asarray(packed)[None], mcfg=mcfg,
                          mask=jnp.asarray(maskp)[None],
                          offsets=jnp.asarray(offs))[0]
        for i, n in enumerate(sizes):
            f1, m1 = pack_ragged([feats[i]], 16, geometric=False)
            solo = pc_apply(params, jnp.asarray(f1), mcfg=mcfg,
                            mask=jnp.asarray(m1))[0][:n]
            a = int(offs[i])
            np.testing.assert_allclose(np.asarray(out_pk[a:a + n]),
                                       np.asarray(solo), atol=1e-5, rtol=1e-5)


def test_attention_layer_offsets_guards():
    import dataclasses

    from repro.configs import get_config
    from repro.models.attention_layer import attention_layer_apply, \
        attention_layer_init

    mcfg = get_config("shapenet-bsa").scaled(
        n_layers=1, d_model=32, n_heads=2, head_dim=16, n_kv_heads=2, d_ff=64)
    mcfg = mcfg.scaled(bsa=dataclasses.replace(mcfg.bsa, ball_size=16,
                                               local_window=16))
    p = attention_layer_init(jax.random.PRNGKey(0), mcfg,
                             param_dtype=jnp.float32)
    x = jnp.zeros((2, 32, 32))
    offs = jnp.asarray([0, 16, 32], jnp.int32)
    with pytest.raises(ValueError):            # packed input must be B == 1
        attention_layer_apply(p, x, mcfg=mcfg, causal=False, offsets=offs)
    with pytest.raises(NotImplementedError):   # causal varlen not supported
        attention_layer_apply(p, x[:1], mcfg=mcfg, causal=True, offsets=offs)


# ---------------------------------------------------------------------------
# satellites: tuning-cache layout key, dataset deprecation
# ---------------------------------------------------------------------------

def test_tuning_cache_layout_key(tmp_path, monkeypatch):
    """Padded-bucket and packed-varlen launches of the same shape must NEVER
    share a tile cache entry — the layouts' cost profiles differ."""
    import json

    from repro.kernels import tuning

    monkeypatch.setenv(tuning.ENV_CACHE, str(tmp_path / "t.json"))
    tuning.clear_memory_cache()
    kw = dict(n_q=256, n_k=256, d=32, dtype=jnp.float32, interpret=True)
    k_pad = tuning._key("flash", variant="plain", **kw)
    k_pk = tuning._key("flash", variant="plain", layout="varlen", **kw)
    assert k_pad != k_pk and k_pk.endswith("/varlen")

    monkeypatch.setenv(tuning.ENV_AUTOTUNE, "1")
    tuning.get_tiles("flash", measure=lambda tq, tk: 1.0, variant="plain",
                     **kw)
    tuning.get_tiles("flash", measure=lambda tq, tk: 1.0, variant="plain",
                     layout="varlen", **kw)
    cache = json.loads((tmp_path / "t.json").read_text())
    assert k_pad in cache and k_pk in cache    # two distinct entries


def test_dataset_pad_to_deprecation():
    from repro.data import ShapeNetCarDataset
    ds = ShapeNetCarDataset("train", ball_size=32, n_points_range=(70, 120))
    with pytest.warns(DeprecationWarning, match="packed-varlen"):
        next(ds.batches(2, seed=0, pad_to=ds.max_padded_len))
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # no warning without pad_to
        next(ds.batches(2, seed=0))
