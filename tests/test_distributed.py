"""Distribution tests.  Multi-device cases run in SUBPROCESSES so the main
pytest process keeps its single-device jax runtime (the device count is
frozen at first backend init)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import logical_to_spec
from repro.launch.mesh import make_mesh


def _run(src: str, n_dev: int = 8) -> str:
    """Run python source with n_dev fake devices; return stdout."""
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        env={"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=900, cwd=".")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# sharding rules (single device, pure logic)
# ---------------------------------------------------------------------------

def test_logical_to_spec_divisibility_guard():
    mesh = make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    rules = {"heads": ("model",), "batch": ("data",), "d_model": None}
    # 56 heads not divisible by 16 → replicated; 64 heads → sharded
    spec = logical_to_spec(("batch", "seq", "heads"), (256, 4096, 56), FakeMesh, rules)
    assert spec == P("data", None, None)
    spec = logical_to_spec(("batch", "seq", "heads"), (256, 4096, 64), FakeMesh, rules)
    assert spec == P("data", None, "model")


def test_param_shardings_patterns():
    from repro.distributed.params import param_shardings
    mesh = make_mesh((1,), ("model",))

    class M:
        shape = {"model": 1}
        def __eq__(self, o): return True
    params = {
        "embed": {"table": jax.ShapeDtypeStruct((1024, 64), np.float32)},
        "layers": {"pos0": {"attn": {
            "wq": {"w": jax.ShapeDtypeStruct((4, 64, 128), np.float32)},
            "wo": {"w": jax.ShapeDtypeStruct((4, 128, 64), np.float32)}}}},
    }
    sh = param_shardings(params, mesh)
    # with model axis of size 1 everything is effectively replicated but the
    # tree structure must match exactly
    assert jax.tree.structure(sh) == jax.tree.structure(params)


# ---------------------------------------------------------------------------
# pipeline parallelism (4 fake devices)
# ---------------------------------------------------------------------------

def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline import pipeline_apply

        S, n_micro, B, d = 4, 8, 2, 16
        mesh = make_mesh((S,), ("stage",))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, d))
        with mesh:
            out = pipeline_apply(stage_fn, Ws, x, mesh=mesh)
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        err = float(jnp.abs(out - ref).max())
        print("PIPE_ERR", err)
        assert err < 1e-5, err
    """, n_dev=4)
    assert "PIPE_ERR" in out


# ---------------------------------------------------------------------------
# compressed cross-pod gradient reduction (2 fake devices = 2 pods)
# ---------------------------------------------------------------------------

def test_compressed_psum_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import compressed_psum

        mesh = make_mesh((2,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 1024))

        def f(gs, err):
            total, resid = compressed_psum(gs[0], err[0], "pod")
            return total[None], resid[None]

        total, resid = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 check_rep=False)(g, jnp.zeros_like(g))
        exact = g.sum(0)
        rel = float(jnp.abs(total[0] - exact).max() / (jnp.abs(exact).max()))
        print("REL", rel)
        assert rel < 0.02, rel                       # int8 quantization error
        # error feedback: residual carries exactly the quantization error
        assert float(jnp.abs(resid).max()) > 0
    """, n_dev=2)
    assert "REL" in out


# ---------------------------------------------------------------------------
# small-mesh dry-run smoke (8 fake devices): lowering machinery end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_small_mesh_dryrun_smoke():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import make_train_step
        from repro.distributed.params import param_shardings, opt_shardings, batch_shardings
        from repro.distributed.sharding import axis_rules
        from repro.optim import adamw_init

        mcfg = smoke_config(get_config("tinyllama-1.1b"))
        api = model_api(mcfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        pstruct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        ostruct = jax.eval_shape(lambda p: adamw_init(p), pstruct)
        p_sh = param_shardings(pstruct, mesh)
        o_sh = opt_shardings(ostruct, mesh)
        bspec = api.batch_specs(8, 256)
        b_sh = batch_shardings(bspec, mesh)
        with mesh, axis_rules(mesh):
            lowered = jax.jit(make_train_step(api),
                              in_shardings=(p_sh, o_sh, b_sh)).lower(
                pstruct, ostruct, bspec)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print("ARGS", ma.argument_size_in_bytes)
        assert ma.argument_size_in_bytes > 0
    """, n_dev=8)
    assert "ARGS" in out


@pytest.mark.slow
def test_small_mesh_execution_correctness():
    """Sharded training step must produce the SAME loss as single-device."""
    src_tpl = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.launch.steps import make_train_step
        from repro.optim import adamw_init
        {mesh_setup}
        mcfg = smoke_config(get_config("tinyllama-1.1b"))
        api = model_api(mcfg)
        params = api.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = api.make_batch(rng, 4, 256)
        step = make_train_step(api)
        {run}
        print("LOSS %.6f" % float(metrics["loss"]))
    """)
    single = _run(src_tpl.format(
        mesh_setup="", run="params, opt, metrics = jax.jit(step)(params, opt, batch)"),
        n_dev=1)
    multi = _run(src_tpl.format(
        mesh_setup="""
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import axis_rules
from repro.distributed.params import param_shardings, opt_shardings
mesh = make_mesh((2, 2), ("data", "model"))
""",
        run="""
p_sh = param_shardings(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), mesh)
with mesh, axis_rules(mesh):
    params = jax.device_put(params, p_sh)
    params, opt, metrics = jax.jit(step)(params, opt, batch)
"""), n_dev=4)
    l1 = float(single.split("LOSS")[1])
    l2 = float(multi.split("LOSS")[1])
    assert abs(l1 - l2) < 5e-3, (l1, l2)
