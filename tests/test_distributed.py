"""Distribution tests.  Multi-device cases run in SUBPROCESSES so the main
pytest process keeps its single-device jax runtime (the device count is
frozen at first backend init)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import logical_to_spec
from repro.launch.mesh import make_mesh


def _run(src: str, n_dev: int = 8) -> str:
    """Run python source with n_dev fake devices; return stdout."""
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        env={"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=900, cwd=".")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# sharding rules (single device, pure logic)
# ---------------------------------------------------------------------------

def test_logical_to_spec_divisibility_guard():
    mesh = make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    rules = {"heads": ("model",), "batch": ("data",), "d_model": None}
    # 56 heads not divisible by 16 → replicated; 64 heads → sharded
    spec = logical_to_spec(("batch", "seq", "heads"), (256, 4096, 56), FakeMesh, rules)
    assert spec == P("data", None, None)
    spec = logical_to_spec(("batch", "seq", "heads"), (256, 4096, 64), FakeMesh, rules)
    assert spec == P("data", None, "model")


def test_param_shardings_patterns():
    from repro.distributed.params import param_shardings
    mesh = make_mesh((1,), ("model",))

    class M:
        shape = {"model": 1}
        def __eq__(self, o): return True
    params = {
        "embed": {"table": jax.ShapeDtypeStruct((1024, 64), np.float32)},
        "layers": {"pos0": {"attn": {
            "wq": {"w": jax.ShapeDtypeStruct((4, 64, 128), np.float32)},
            "wo": {"w": jax.ShapeDtypeStruct((4, 128, 64), np.float32)}}}},
    }
    sh = param_shardings(params, mesh)
    # with model axis of size 1 everything is effectively replicated but the
    # tree structure must match exactly
    assert jax.tree.structure(sh) == jax.tree.structure(params)


# ---------------------------------------------------------------------------
# pipeline parallelism (4 fake devices)
# ---------------------------------------------------------------------------

def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline import pipeline_apply

        S, n_micro, B, d = 4, 8, 2, 16
        mesh = make_mesh((S,), ("stage",))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, d))
        with mesh:
            out = pipeline_apply(stage_fn, Ws, x, mesh=mesh)
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        err = float(jnp.abs(out - ref).max())
        print("PIPE_ERR", err)
        assert err < 1e-5, err
    """, n_dev=4)
    assert "PIPE_ERR" in out


# ---------------------------------------------------------------------------
# compressed cross-pod gradient reduction (2 fake devices = 2 pods)
# ---------------------------------------------------------------------------

def test_compressed_psum_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import compressed_psum

        mesh = make_mesh((2,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 1024))

        def f(gs, err):
            total, resid = compressed_psum(gs[0], err[0], "pod")
            return total[None], resid[None]

        total, resid = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 check_rep=False)(g, jnp.zeros_like(g))
        exact = g.sum(0)
        rel = float(jnp.abs(total[0] - exact).max() / (jnp.abs(exact).max()))
        print("REL", rel)
        assert rel < 0.02, rel                       # int8 quantization error
        # error feedback: residual carries exactly the quantization error
        assert float(jnp.abs(resid).max()) > 0
    """, n_dev=2)
    assert "REL" in out


# ---------------------------------------------------------------------------
# small-mesh dry-run smoke (8 fake devices): lowering machinery end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_small_mesh_dryrun_smoke():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import make_train_step
        from repro.distributed.params import param_shardings, opt_shardings, batch_shardings
        from repro.distributed.sharding import axis_rules
        from repro.optim import adamw_init

        mcfg = smoke_config(get_config("tinyllama-1.1b"))
        api = model_api(mcfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        pstruct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        ostruct = jax.eval_shape(lambda p: adamw_init(p), pstruct)
        p_sh = param_shardings(pstruct, mesh)
        o_sh = opt_shardings(ostruct, mesh)
        bspec = api.batch_specs(8, 256)
        b_sh = batch_shardings(bspec, mesh)
        with mesh, axis_rules(mesh):
            lowered = jax.jit(make_train_step(api),
                              in_shardings=(p_sh, o_sh, b_sh)).lower(
                pstruct, ostruct, bspec)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print("ARGS", ma.argument_size_in_bytes)
        assert ma.argument_size_in_bytes > 0
    """, n_dev=8)
    assert "ARGS" in out


@pytest.mark.slow
def test_small_mesh_execution_correctness():
    """Sharded training step must produce the SAME loss as single-device."""
    src_tpl = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.launch.steps import make_train_step
        from repro.optim import adamw_init
        {mesh_setup}
        mcfg = smoke_config(get_config("tinyllama-1.1b"))
        api = model_api(mcfg)
        params = api.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = api.make_batch(rng, 4, 256)
        step = make_train_step(api)
        {run}
        print("LOSS %.6f" % float(metrics["loss"]))
    """)
    single = _run(src_tpl.format(
        mesh_setup="", run="params, opt, metrics = jax.jit(step)(params, opt, batch)"),
        n_dev=1)
    multi = _run(src_tpl.format(
        mesh_setup="""
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import axis_rules
from repro.distributed.params import param_shardings, opt_shardings
mesh = make_mesh((2, 2), ("data", "model"))
""",
        run="""
p_sh = param_shardings(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), mesh)
with mesh, axis_rules(mesh):
    params = jax.device_put(params, p_sh)
    params, opt, metrics = jax.jit(step)(params, opt, batch)
"""), n_dev=4)
    l1 = float(single.split("LOSS")[1])
    l2 = float(multi.split("LOSS")[1])
    assert abs(l1 - l2) < 5e-3, (l1, l2)


# ---------------------------------------------------------------------------
# mesh builders (satellite: CPU-friendly construction + clear errors)
# ---------------------------------------------------------------------------

def test_make_local_mesh_uses_existing_devices():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    assert mesh.shape["data"] == len(jax.devices())
    with pytest.raises(RuntimeError, match="device"):
        make_local_mesh(len(jax.devices()) + 1)


def test_make_production_mesh_clear_error_on_small_host():
    from repro.launch.mesh import make_production_mesh
    if len(jax.devices()) >= 256:
        pytest.skip("enough devices for a production mesh")
    with pytest.raises(RuntimeError, match="make_local_mesh"):
        make_production_mesh()


# ---------------------------------------------------------------------------
# logical_to_spec fallback paths (divisibility warning + used-axis)
# ---------------------------------------------------------------------------

def test_logical_to_spec_warns_once_on_divisibility_failure():
    import warnings

    class FakeMesh:
        shape = {"model": 12}
    rules = {"heads": ("model",)}
    with pytest.warns(RuntimeWarning, match="'heads'.*50.*model.*12"):
        spec = logical_to_spec(("heads",), (50,), FakeMesh, rules)
    assert spec == P(None)
    # one-shot: the same failing combo never warns again
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert logical_to_spec(("heads",), (50,), FakeMesh, rules) == P(None)


def test_logical_to_spec_used_axis_fallback_is_silent():
    import warnings

    class FakeMesh:
        shape = {"model": 4}
    rules = {"heads": ("model",), "d_ff": ("model",)}
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        # d_ff loses the already-used model axis → structural replication,
        # no warning (nothing actionable about it)
        spec = logical_to_spec(("heads", "d_ff"), (8, 64), FakeMesh, rules)
    assert spec == P("model", None)


# ---------------------------------------------------------------------------
# "sharded" backend: registration, mesh requirement, 1-device passthrough
# ---------------------------------------------------------------------------

def _tiny_bsa_case(seed=0, N=128):
    import jax.numpy as jnp
    from repro.core import BSAConfig
    from repro.core.bsa import bsa_init
    cfg = BSAConfig(ball_size=32, local_window=32, cmp_block=8, top_k=2,
                    group_size=8, backend="jnp")
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    params = bsa_init(ks[0], cfg, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_model=32)
    q = jax.random.normal(ks[1], (2, N, 4, 8), jnp.float32)
    k = jax.random.normal(ks[2], (2, N, 2, 8), jnp.float32)
    v = jax.random.normal(ks[3], (2, N, 2, 8), jnp.float32)
    return cfg, params, q, k, v


def test_sharded_backend_registered_via_registry():
    from repro.core.backend import get_backend
    bk = get_backend("sharded")
    assert bk.name == "sharded" and bk.requires_mesh


def test_sharded_backend_requires_mesh_context():
    from repro.core.backend import use_backend
    from repro.core.bsa import bsa_attention
    cfg, params, q, k, v = _tiny_bsa_case()
    with use_backend("sharded"):
        with pytest.raises(RuntimeError, match="mesh_context"):
            bsa_attention(params, q, k, v, cfg=cfg)


def test_sharded_single_device_mesh_passthrough():
    import jax.numpy as jnp
    from repro.core.backend import use_backend
    from repro.core.bsa import bsa_attention
    from repro.distributed import mesh_context
    from repro.launch.mesh import make_local_mesh
    cfg, params, q, k, v = _tiny_bsa_case()
    ref = bsa_attention(params, q, k, v, cfg=cfg)
    with mesh_context(make_local_mesh(1)), use_backend("sharded"):
        out = bsa_attention(params, q, k, v, cfg=cfg)
    assert float(jnp.abs(ref - out).max()) < 1e-6


def test_engines_fail_fast_without_mesh():
    from repro.serving.engine import GeometryEngine, ServingEngine

    class _API:      # the fail-fast fires before anything else is touched
        class mcfg:
            class bsa:
                backend = None
    with pytest.raises(ValueError, match="mesh_context"):
        ServingEngine(_API, None, batch_slots=1, max_len=64,
                      backend="sharded")
    with pytest.raises(ValueError, match="mesh_context"):
        GeometryEngine(_API, None, backend="sharded")


# ---------------------------------------------------------------------------
# sharded == single-device parity (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_backend_parity_8dev():
    """fwd + full grads vs the unsharded jnp oracle (atol 1e-5 fp32) for
    bsa_attention (dense + ragged) and nsa_causal_attention, the packed-
    varlen fallback seam, and the indivisible-shape fallback warning."""
    out = _run("""
        import warnings
        import jax, jax.numpy as jnp
        from repro.core import BSAConfig
        from repro.core.bsa import bsa_attention, bsa_attention_varlen, bsa_init
        from repro.core.nsa_causal import nsa_causal_attention, nsa_init
        from repro.core.backend import use_backend
        from repro.distributed import mesh_context
        from repro.launch.mesh import make_local_mesh

        B, N, Hq, Hkv, D = 2, 512, 4, 2, 16
        cfg = BSAConfig(ball_size=64, local_window=64, cmp_block=8, top_k=4,
                        group_size=8, backend="jnp")
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        bparams = bsa_init(ks[0], cfg, n_heads=Hq, n_kv_heads=Hkv,
                           head_dim=D, d_model=Hq * D)
        nparams = nsa_init(ks[4], cfg, n_heads=Hq, n_kv_heads=Hkv,
                           head_dim=D, d_model=Hq * D)
        q = jax.random.normal(ks[1], (B, N, Hq, D), jnp.float32)
        k = jax.random.normal(ks[2], (B, N, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[3], (B, N, Hkv, D), jnp.float32)
        # ragged batch: row 1 real only up to 320 of 512
        mask = jnp.arange(N)[None, :] < jnp.array([N, 320])[:, None]
        mesh = make_local_mesh()
        assert mesh.shape["data"] == 8

        def tree_err(a, b):
            return max(jax.tree.leaves(jax.tree.map(
                lambda x, y: float(jnp.abs(x - y).max()), a, b)))

        with warnings.catch_warnings(record=True) as wrec:
            warnings.simplefilter("always")
            for name, fn, p in [("bsa", bsa_attention, bparams),
                                ("nsa", nsa_causal_attention, nparams)]:
                for m in (None, mask):
                    def loss(p, q, k, v):
                        o = fn(p, q, k, v, cfg=cfg, mask=m)
                        return (o ** 2).sum() / N   # O(1) grads: atol is
                                                     # a ~1e-5 RELATIVE bar
                    ref_o = fn(p, q, k, v, cfg=cfg, mask=m)
                    ref_g = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))(p, q, k, v)
                    with mesh_context(mesh), use_backend("sharded"):
                        sh_o = jax.jit(lambda p, q, k, v: fn(
                            p, q, k, v, cfg=cfg, mask=m))(p, q, k, v)
                        sh_g = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))(p, q, k, v)
                    eo, eg = tree_err(ref_o, sh_o), tree_err(ref_g, sh_g)
                    tag = "dense" if m is None else "ragged"
                    print(name, tag, "fwd", eo, "grad", eg)
                    assert eo < 1e-5 and eg < 1e-5, (name, tag, eo, eg)
        # every op (incl. token-causal flash + selection, once fallbacks)
        # must now shard on divisible shapes — zero falls-back warnings
        assert not any("falls back" in str(x.message) for x in wrec), \\
            [str(x.message) for x in wrec]

        # packed-varlen seam: now SEGMENT-SHARDED (LPT re-layout), not a
        # fallback — parity must hold with no falls-back warning at all
        offs = jnp.array([0, 256, 448, 512], jnp.int32)
        qp, kp, vp = q[0], k[0], v[0]
        ref_vl = bsa_attention_varlen(bparams, qp, kp, vp, cfg=cfg, offsets=offs)
        with mesh_context(mesh), use_backend("sharded"):
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                sh_vl = bsa_attention_varlen(bparams, qp, kp, vp, cfg=cfg,
                                             offsets=offs)
            assert not any("falls back" in str(x.message) for x in w), \\
                [str(x.message) for x in w]
        assert float(jnp.abs(ref_vl - sh_vl).max()) < 1e-5

        # indivisible sequence → warn-once fallback, numerics unchanged
        from repro.core.backend import get_backend
        bk = get_backend("sharded")
        q3, k3, v3 = q[:, :192], k[:, :192], v[:, :192]   # 192/8 = 24, not ball-multiple
        with mesh_context(mesh):
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                o_sh = bk.ball(q3, k3, v3, None, ball_size=64)
            assert any("falls back" in str(x.message) for x in w), w
        o_ref = get_backend("jnp").ball(q3, k3, v3, None, ball_size=64)
        assert float(jnp.abs(o_sh - o_ref).max()) < 1e-6
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


@pytest.mark.slow
def test_sharded_serve_decode_parity_8dev():
    """ServingEngine(backend="sharded") paged decode over row-partitioned
    KV pools generates the same tokens as the jnp engine."""
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.serving import ServingEngine
        from repro.distributed import mesh_context
        from repro.launch.mesh import make_local_mesh

        mcfg = smoke_config(get_config("tinyllama-1.1b")).scaled(n_layers=1)
        api = model_api(mcfg)
        params = api.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, n, dtype=np.int32)
                   for n in (40, 70, 20)]
        ref_eng = ServingEngine(api, params, batch_slots=2, max_len=128,
                                paged=True, backend="jnp")
        ref = ref_eng.serve(prompts, max_new_tokens=6)
        with mesh_context(make_local_mesh()):
            eng = ServingEngine(api, params, batch_slots=2, max_len=128,
                                paged=True, backend="sharded")
        # pools divide the 8-way axis after the constructor's bump
        p = 8
        assert ((eng.num_blocks + 1) * eng.page) % p == 0
        res = eng.serve(prompts, max_new_tokens=6)   # outside the with-block
        eng.kv.check()
        for i in range(len(prompts)):
            np.testing.assert_array_equal(res[i], ref[i], err_msg=f"req {i}")
        print("SERVE_PARITY_OK")
    """)
    assert "SERVE_PARITY_OK" in out


# ---------------------------------------------------------------------------
# ring context parallelism (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ring_flash_parity_8dev():
    """ring_flash (causal + non-causal, ragged key mask) vs the unsharded
    jnp oracle: fwd AND full grads within atol 1e-5, with the causal hop
    table skipping ~half the hops."""
    out = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.backend import get_backend
        from repro.distributed import ring
        from repro.launch.mesh import make_local_mesh
        from repro.kernels import occupancy
        from repro.numerics import key_padding_bias

        mesh, axis, p = make_local_mesh(8), "data", 8
        rng = np.random.default_rng(0)
        B, N, Hq, Hkv, D = 2, 128, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(B, N, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, N, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, N, Hkv, D)), jnp.float32)
        mask = jnp.asarray(rng.random((B, N)) > 0.2)
        kb = key_padding_bias(mask, B, N)
        jb = get_backend("jnp")
        seq = P(None, axis)

        for causal in (True, False):
            live = occupancy.ring_hop_live(p, N // p, causal=causal)
            assert live.sum() == (p * (p + 1) // 2 if causal else p * p)

            def run(q, k, v):
                body = lambda q, k, v, kb: ring.ring_flash(
                    q, k, v, kb, axis=axis, p=p, causal=causal, live=live)
                return shard_map(body, mesh=mesh,
                                 in_specs=(seq, seq, seq, seq),
                                 out_specs=seq, check_rep=False)(q, k, v, kb)

            ref = jb.flash(q, k, v, key_valid=mask, causal=causal)
            e = float(jnp.abs(run(q, k, v) - ref).max())
            w = jnp.asarray(np.random.default_rng(1).normal(size=ref.shape))
            g1 = jax.grad(lambda q, k, v: (run(q, k, v) * w).sum(),
                          argnums=(0, 1, 2))(q, k, v)
            g2 = jax.grad(lambda q, k, v: (jb.flash(
                q, k, v, key_valid=mask, causal=causal) * w).sum(),
                argnums=(0, 1, 2))(q, k, v)
            ge = max(float(jnp.abs(a - b).max()) for a, b in zip(g1, g2))
            print("causal", causal, "fwd", e, "grad", ge)
            assert e < 1e-5 and ge < 1e-5, (causal, e, ge)
        print("RING_FLASH_OK")
    """)
    assert "RING_FLASH_OK" in out


@pytest.mark.slow
def test_ring_selection_parity_8dev():
    """ring_selection (sharded+rotating selection K/V, indices re-based to
    ring-local coordinates) vs the replicated jnp oracle, fwd + grads."""
    out = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.backend import get_backend
        from repro.distributed import ring
        from repro.launch.mesh import make_local_mesh

        mesh, axis, p = make_local_mesh(8), "data", 8
        rng = np.random.default_rng(0)
        B, N, Hq, Hkv, D = 2, 128, 4, 2, 16
        ell, g, k_star = 8, 16, 4
        G, nb = N // g, N // ell
        q = jnp.asarray(rng.normal(size=(B, N, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, N, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, N, Hkv, D)), jnp.float32)
        mask = jnp.asarray(rng.random((B, N)) > 0.2)
        ti = jnp.asarray(rng.integers(0, nb, size=(B, G, Hkv, k_star)), jnp.int32)
        sv = jnp.asarray(rng.random((B, G, Hkv, k_star)) > 0.25)
        jb = get_backend("jnp")
        seq = P(None, axis)

        def run(q, k, v):
            body = lambda q, ti, sv, k, v, m, qv: ring.ring_selection(
                q, k, v, ti, sv, m, qv, axis=axis, p=p,
                block_size=ell, group_size=g)
            return shard_map(body, mesh=mesh,
                             in_specs=(seq,) * 7, out_specs=seq,
                             check_rep=False)(q, ti, sv, k, v, mask, mask)

        ref = jb.selection(q, k, v, ti, sv, mask, block_size=ell, group_size=g)
        e = float(jnp.abs(run(q, k, v) - ref).max())
        w = jnp.asarray(np.random.default_rng(1).normal(size=ref.shape))
        g1 = jax.grad(lambda q, k, v: (run(q, k, v) * w).sum(),
                      argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda q, k, v: (jb.selection(
            q, k, v, ti, sv, mask, block_size=ell, group_size=g) * w).sum(),
            argnums=(0, 1, 2))(q, k, v)
        ge = max(float(jnp.abs(a - b).max()) for a, b in zip(g1, g2))
        print("fwd", e, "grad", ge)
        assert e < 1e-5 and ge < 1e-5, (e, ge)
        print("RING_SEL_OK")
    """)
    assert "RING_SEL_OK" in out


@pytest.mark.slow
def test_segment_sharded_varlen_parity_8dev():
    """All four packed-varlen ops on the sharded backend (LPT segment
    re-layout, zero collectives) vs the unsharded jnp oracle — fwd + a
    grad probe, with NO falls-back warning on divisible sizes."""
    out = _run("""
        import warnings
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core.backend import get_backend
        from repro.distributed import mesh_context
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(8)
        rng = np.random.default_rng(0)
        T, Hq, Hkv, D = 512, 4, 2, 16
        offs = (0, 256, 320, 448, 512)
        offsets = jnp.asarray(offs, jnp.int32)
        q = jnp.asarray(rng.normal(size=(T, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(T, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(T, Hkv, D)), jnp.float32)
        m = jnp.asarray(rng.random(T) > 0.1)
        jb, sb = get_backend("jnp"), get_backend("sharded")
        ell, g, k_star, ball = 8, 16, 4, 64
        k_off = offsets // ell
        kc = jnp.asarray(rng.normal(size=(T // ell, Hkv, D)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(T // ell, Hkv, D)), jnp.float32)
        blkv = jnp.asarray(rng.random(T // ell) > 0.1)
        Gv = T // g
        so = np.searchsorted(np.asarray(offs)[1:], np.arange(Gv) * g, "right")
        lo = np.asarray(offs)[so] // ell
        span = np.maximum(np.asarray(offs)[so + 1] // ell - lo, 1)
        ti = jnp.asarray(lo[:, None, None] + rng.integers(
            0, 1000, size=(Gv, Hkv, k_star)) % span[:, None, None], jnp.int32)
        sv = jnp.asarray(rng.random((Gv, Hkv, k_star)) > 0.25)

        cases = [
            ("ball", lambda b: b.ball_varlen(q, k, v, offsets, m,
                                             ball_size=ball)),
            ("flash", lambda b: b.flash_varlen(q, kc, vc, offsets, k_off,
                                               key_valid=blkv)),
            ("window", lambda b: b.local_window_varlen(q, k, v, offsets,
                                                       window=32, mask=m)),
            ("sel", lambda b: b.selection_varlen(q, k, v, ti, sv, offsets,
                                                 m, block_size=ell,
                                                 group_size=g)),
        ]
        with mesh_context(mesh):
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                for name, fn in cases:
                    e = float(jnp.abs(fn(sb) - fn(jb)).max())
                    print(name, e)
                    assert e < 1e-5, (name, e)
                gq1 = jax.grad(lambda q_: (sb.ball_varlen(
                    q_, k, v, offsets, m, ball_size=ball) ** 2).sum())(q)
            assert not any("falls back" in str(x.message) for x in w), \\
                [str(x.message) for x in w]
        gq2 = jax.grad(lambda q_: (jb.ball_varlen(
            q_, k, v, offsets, m, ball_size=ball) ** 2).sum())(q)
        assert float(jnp.abs(gq1 - gq2).max()) < 1e-5
        print("VARLEN_OK")
    """)
    assert "VARLEN_OK" in out


# ---------------------------------------------------------------------------
# LPT segment partitioner + warn-once keying (single device, pure logic)
# ---------------------------------------------------------------------------

def test_lpt_beats_round_robin_on_skew():
    from repro.distributed import plan_segments, round_robin_partition
    # skewed ragged batch: one giant segment + many small ones.  Cost is
    # quadratic in segment length, which round-robin's index-order deal
    # gets badly wrong.
    sizes = (512, 64, 64, 64, 64, 64, 64, 64, 64, 64)
    lpt = plan_segments(tuple(np.cumsum((0,) + sizes).tolist()), 4)
    rr = plan_segments(tuple(np.cumsum((0,) + sizes).tolist()), 4,
                       partition=round_robin_partition)
    # cost_balance = max shard load / mean load (1.0 = perfect)
    assert lpt.cost_balance < rr.cost_balance
    # LPT puts the giant segment alone on one shard
    giant_shard = lpt.assign[0]
    assert all(a != giant_shard for a in lpt.assign[1:])


def test_plan_segments_is_cached():
    from repro.distributed import plan_segments
    a = plan_segments((0, 128, 256), 2)
    b = plan_segments((0, 128, 256), 2)
    assert a is b


def test_warn_once_keys_on_op_and_reason():
    import warnings
    from repro.distributed.sharded_backend import _warn_once, reset_warnings
    reset_warnings()
    # two DISTINCT causes for one op must BOTH warn ...
    with pytest.warns(RuntimeWarning, match="indivisible-dim"):
        _warn_once("flash", "indivisible-dim", "seq 100 % 8 != 0")
    with pytest.warns(RuntimeWarning, match="causal-qk-mismatch"):
        _warn_once("flash", "causal-qk-mismatch", "N=1 != L=64")
    # ... while a repeat of the same (op, code) stays silent, even with a
    # different dynamic detail string
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _warn_once("flash", "indivisible-dim", "seq 204 % 8 != 0")
    reset_warnings()
