"""Distribution tests.  Multi-device cases run in SUBPROCESSES so the main
pytest process keeps its single-device jax runtime (the device count is
frozen at first backend init)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import logical_to_spec
from repro.launch.mesh import make_mesh


def _run(src: str, n_dev: int = 8) -> str:
    """Run python source with n_dev fake devices; return stdout."""
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        env={"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=900, cwd=".")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# sharding rules (single device, pure logic)
# ---------------------------------------------------------------------------

def test_logical_to_spec_divisibility_guard():
    mesh = make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    rules = {"heads": ("model",), "batch": ("data",), "d_model": None}
    # 56 heads not divisible by 16 → replicated; 64 heads → sharded
    spec = logical_to_spec(("batch", "seq", "heads"), (256, 4096, 56), FakeMesh, rules)
    assert spec == P("data", None, None)
    spec = logical_to_spec(("batch", "seq", "heads"), (256, 4096, 64), FakeMesh, rules)
    assert spec == P("data", None, "model")


def test_param_shardings_patterns():
    from repro.distributed.params import param_shardings
    mesh = make_mesh((1,), ("model",))

    class M:
        shape = {"model": 1}
        def __eq__(self, o): return True
    params = {
        "embed": {"table": jax.ShapeDtypeStruct((1024, 64), np.float32)},
        "layers": {"pos0": {"attn": {
            "wq": {"w": jax.ShapeDtypeStruct((4, 64, 128), np.float32)},
            "wo": {"w": jax.ShapeDtypeStruct((4, 128, 64), np.float32)}}}},
    }
    sh = param_shardings(params, mesh)
    # with model axis of size 1 everything is effectively replicated but the
    # tree structure must match exactly
    assert jax.tree.structure(sh) == jax.tree.structure(params)


# ---------------------------------------------------------------------------
# pipeline parallelism (4 fake devices)
# ---------------------------------------------------------------------------

def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline import pipeline_apply

        S, n_micro, B, d = 4, 8, 2, 16
        mesh = make_mesh((S,), ("stage",))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, d, d)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, d))
        with mesh:
            out = pipeline_apply(stage_fn, Ws, x, mesh=mesh)
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        err = float(jnp.abs(out - ref).max())
        print("PIPE_ERR", err)
        assert err < 1e-5, err
    """, n_dev=4)
    assert "PIPE_ERR" in out


# ---------------------------------------------------------------------------
# compressed cross-pod gradient reduction (2 fake devices = 2 pods)
# ---------------------------------------------------------------------------

def test_compressed_psum_close_to_exact():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.compress import compressed_psum

        mesh = make_mesh((2,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 1024))

        def f(gs, err):
            total, resid = compressed_psum(gs[0], err[0], "pod")
            return total[None], resid[None]

        total, resid = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 check_rep=False)(g, jnp.zeros_like(g))
        exact = g.sum(0)
        rel = float(jnp.abs(total[0] - exact).max() / (jnp.abs(exact).max()))
        print("REL", rel)
        assert rel < 0.02, rel                       # int8 quantization error
        # error feedback: residual carries exactly the quantization error
        assert float(jnp.abs(resid).max()) > 0
    """, n_dev=2)
    assert "REL" in out


# ---------------------------------------------------------------------------
# small-mesh dry-run smoke (8 fake devices): lowering machinery end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_small_mesh_dryrun_smoke():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import make_train_step
        from repro.distributed.params import param_shardings, opt_shardings, batch_shardings
        from repro.distributed.sharding import axis_rules
        from repro.optim import adamw_init

        mcfg = smoke_config(get_config("tinyllama-1.1b"))
        api = model_api(mcfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        pstruct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        ostruct = jax.eval_shape(lambda p: adamw_init(p), pstruct)
        p_sh = param_shardings(pstruct, mesh)
        o_sh = opt_shardings(ostruct, mesh)
        bspec = api.batch_specs(8, 256)
        b_sh = batch_shardings(bspec, mesh)
        with mesh, axis_rules(mesh):
            lowered = jax.jit(make_train_step(api),
                              in_shardings=(p_sh, o_sh, b_sh)).lower(
                pstruct, ostruct, bspec)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print("ARGS", ma.argument_size_in_bytes)
        assert ma.argument_size_in_bytes > 0
    """, n_dev=8)
    assert "ARGS" in out


@pytest.mark.slow
def test_small_mesh_execution_correctness():
    """Sharded training step must produce the SAME loss as single-device."""
    src_tpl = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.launch.steps import make_train_step
        from repro.optim import adamw_init
        {mesh_setup}
        mcfg = smoke_config(get_config("tinyllama-1.1b"))
        api = model_api(mcfg)
        params = api.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = api.make_batch(rng, 4, 256)
        step = make_train_step(api)
        {run}
        print("LOSS %.6f" % float(metrics["loss"]))
    """)
    single = _run(src_tpl.format(
        mesh_setup="", run="params, opt, metrics = jax.jit(step)(params, opt, batch)"),
        n_dev=1)
    multi = _run(src_tpl.format(
        mesh_setup="""
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import axis_rules
from repro.distributed.params import param_shardings, opt_shardings
mesh = make_mesh((2, 2), ("data", "model"))
""",
        run="""
p_sh = param_shardings(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), mesh)
with mesh, axis_rules(mesh):
    params = jax.device_put(params, p_sh)
    params, opt, metrics = jax.jit(step)(params, opt, batch)
"""), n_dev=4)
    l1 = float(single.split("LOSS")[1])
    l2 = float(multi.split("LOSS")[1])
    assert abs(l1 - l2) < 5e-3, (l1, l2)


# ---------------------------------------------------------------------------
# mesh builders (satellite: CPU-friendly construction + clear errors)
# ---------------------------------------------------------------------------

def test_make_local_mesh_uses_existing_devices():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    assert mesh.shape["data"] == len(jax.devices())
    with pytest.raises(RuntimeError, match="device"):
        make_local_mesh(len(jax.devices()) + 1)


def test_make_production_mesh_clear_error_on_small_host():
    from repro.launch.mesh import make_production_mesh
    if len(jax.devices()) >= 256:
        pytest.skip("enough devices for a production mesh")
    with pytest.raises(RuntimeError, match="make_local_mesh"):
        make_production_mesh()


# ---------------------------------------------------------------------------
# logical_to_spec fallback paths (divisibility warning + used-axis)
# ---------------------------------------------------------------------------

def test_logical_to_spec_warns_once_on_divisibility_failure():
    import warnings

    class FakeMesh:
        shape = {"model": 12}
    rules = {"heads": ("model",)}
    with pytest.warns(RuntimeWarning, match="'heads'.*50.*model.*12"):
        spec = logical_to_spec(("heads",), (50,), FakeMesh, rules)
    assert spec == P(None)
    # one-shot: the same failing combo never warns again
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert logical_to_spec(("heads",), (50,), FakeMesh, rules) == P(None)


def test_logical_to_spec_used_axis_fallback_is_silent():
    import warnings

    class FakeMesh:
        shape = {"model": 4}
    rules = {"heads": ("model",), "d_ff": ("model",)}
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        # d_ff loses the already-used model axis → structural replication,
        # no warning (nothing actionable about it)
        spec = logical_to_spec(("heads", "d_ff"), (8, 64), FakeMesh, rules)
    assert spec == P("model", None)


# ---------------------------------------------------------------------------
# "sharded" backend: registration, mesh requirement, 1-device passthrough
# ---------------------------------------------------------------------------

def _tiny_bsa_case(seed=0, N=128):
    import jax.numpy as jnp
    from repro.core import BSAConfig
    from repro.core.bsa import bsa_init
    cfg = BSAConfig(ball_size=32, local_window=32, cmp_block=8, top_k=2,
                    group_size=8, backend="jnp")
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    params = bsa_init(ks[0], cfg, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_model=32)
    q = jax.random.normal(ks[1], (2, N, 4, 8), jnp.float32)
    k = jax.random.normal(ks[2], (2, N, 2, 8), jnp.float32)
    v = jax.random.normal(ks[3], (2, N, 2, 8), jnp.float32)
    return cfg, params, q, k, v


def test_sharded_backend_registered_via_registry():
    from repro.core.backend import get_backend
    bk = get_backend("sharded")
    assert bk.name == "sharded" and bk.requires_mesh


def test_sharded_backend_requires_mesh_context():
    from repro.core.backend import use_backend
    from repro.core.bsa import bsa_attention
    cfg, params, q, k, v = _tiny_bsa_case()
    with use_backend("sharded"):
        with pytest.raises(RuntimeError, match="mesh_context"):
            bsa_attention(params, q, k, v, cfg=cfg)


def test_sharded_single_device_mesh_passthrough():
    import jax.numpy as jnp
    from repro.core.backend import use_backend
    from repro.core.bsa import bsa_attention
    from repro.distributed import mesh_context
    from repro.launch.mesh import make_local_mesh
    cfg, params, q, k, v = _tiny_bsa_case()
    ref = bsa_attention(params, q, k, v, cfg=cfg)
    with mesh_context(make_local_mesh(1)), use_backend("sharded"):
        out = bsa_attention(params, q, k, v, cfg=cfg)
    assert float(jnp.abs(ref - out).max()) < 1e-6


def test_engines_fail_fast_without_mesh():
    from repro.serving.engine import GeometryEngine, ServingEngine

    class _API:      # the fail-fast fires before anything else is touched
        class mcfg:
            class bsa:
                backend = None
    with pytest.raises(ValueError, match="mesh_context"):
        ServingEngine(_API, None, batch_slots=1, max_len=64,
                      backend="sharded")
    with pytest.raises(ValueError, match="mesh_context"):
        GeometryEngine(_API, None, backend="sharded")


# ---------------------------------------------------------------------------
# sharded == single-device parity (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_backend_parity_8dev():
    """fwd + full grads vs the unsharded jnp oracle (atol 1e-5 fp32) for
    bsa_attention (dense + ragged) and nsa_causal_attention, the packed-
    varlen fallback seam, and the indivisible-shape fallback warning."""
    out = _run("""
        import warnings
        import jax, jax.numpy as jnp
        from repro.core import BSAConfig
        from repro.core.bsa import bsa_attention, bsa_attention_varlen, bsa_init
        from repro.core.nsa_causal import nsa_causal_attention, nsa_init
        from repro.core.backend import use_backend
        from repro.distributed import mesh_context
        from repro.launch.mesh import make_local_mesh

        B, N, Hq, Hkv, D = 2, 512, 4, 2, 16
        cfg = BSAConfig(ball_size=64, local_window=64, cmp_block=8, top_k=4,
                        group_size=8, backend="jnp")
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        bparams = bsa_init(ks[0], cfg, n_heads=Hq, n_kv_heads=Hkv,
                           head_dim=D, d_model=Hq * D)
        nparams = nsa_init(ks[4], cfg, n_heads=Hq, n_kv_heads=Hkv,
                           head_dim=D, d_model=Hq * D)
        q = jax.random.normal(ks[1], (B, N, Hq, D), jnp.float32)
        k = jax.random.normal(ks[2], (B, N, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[3], (B, N, Hkv, D), jnp.float32)
        # ragged batch: row 1 real only up to 320 of 512
        mask = jnp.arange(N)[None, :] < jnp.array([N, 320])[:, None]
        mesh = make_local_mesh()
        assert mesh.shape["data"] == 8

        def tree_err(a, b):
            return max(jax.tree.leaves(jax.tree.map(
                lambda x, y: float(jnp.abs(x - y).max()), a, b)))

        for name, fn, p in [("bsa", bsa_attention, bparams),
                            ("nsa", nsa_causal_attention, nparams)]:
            for m in (None, mask):
                def loss(p, q, k, v):
                    o = fn(p, q, k, v, cfg=cfg, mask=m)
                    return (o ** 2).sum() / N       # O(1) grads: atol is
                                                     # a ~1e-5 RELATIVE bar
                ref_o = fn(p, q, k, v, cfg=cfg, mask=m)
                ref_g = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))(p, q, k, v)
                with mesh_context(mesh), use_backend("sharded"):
                    sh_o = jax.jit(lambda p, q, k, v: fn(
                        p, q, k, v, cfg=cfg, mask=m))(p, q, k, v)
                    sh_g = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))(p, q, k, v)
                eo, eg = tree_err(ref_o, sh_o), tree_err(ref_g, sh_g)
                tag = "dense" if m is None else "ragged"
                print(name, tag, "fwd", eo, "grad", eg)
                assert eo < 1e-5 and eg < 1e-5, (name, tag, eo, eg)

        # packed-varlen seam: sharded falls back to the jnp oracle ops
        offs = jnp.array([0, 256, 448, 512], jnp.int32)
        qp, kp, vp = q[0], k[0], v[0]
        ref_vl = bsa_attention_varlen(bparams, qp, kp, vp, cfg=cfg, offsets=offs)
        with mesh_context(mesh), use_backend("sharded"):
            sh_vl = bsa_attention_varlen(bparams, qp, kp, vp, cfg=cfg, offsets=offs)
        assert float(jnp.abs(ref_vl - sh_vl).max()) < 1e-6

        # indivisible sequence → warn-once fallback, numerics unchanged
        from repro.core.backend import get_backend
        bk = get_backend("sharded")
        q3, k3, v3 = q[:, :192], k[:, :192], v[:, :192]   # 192/8 = 24, not ball-multiple
        with mesh_context(mesh):
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                o_sh = bk.ball(q3, k3, v3, None, ball_size=64)
            assert any("falls back" in str(x.message) for x in w), w
        o_ref = get_backend("jnp").ball(q3, k3, v3, None, ball_size=64)
        assert float(jnp.abs(o_sh - o_ref).max()) < 1e-6
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


@pytest.mark.slow
def test_sharded_serve_decode_parity_8dev():
    """ServingEngine(backend="sharded") paged decode over row-partitioned
    KV pools generates the same tokens as the jnp engine."""
    out = _run("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.configs.reduce import smoke_config
        from repro.models.api import model_api
        from repro.serving import ServingEngine
        from repro.distributed import mesh_context
        from repro.launch.mesh import make_local_mesh

        mcfg = smoke_config(get_config("tinyllama-1.1b")).scaled(n_layers=1)
        api = model_api(mcfg)
        params = api.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, n, dtype=np.int32)
                   for n in (40, 70, 20)]
        ref_eng = ServingEngine(api, params, batch_slots=2, max_len=128,
                                paged=True, backend="jnp")
        ref = ref_eng.serve(prompts, max_new_tokens=6)
        with mesh_context(make_local_mesh()):
            eng = ServingEngine(api, params, batch_slots=2, max_len=128,
                                paged=True, backend="sharded")
        # pools divide the 8-way axis after the constructor's bump
        p = 8
        assert ((eng.num_blocks + 1) * eng.page) % p == 0
        res = eng.serve(prompts, max_new_tokens=6)   # outside the with-block
        eng.kv.check()
        for i in range(len(prompts)):
            np.testing.assert_array_equal(res[i], ref[i], err_msg=f"req {i}")
        print("SERVE_PARITY_OK")
    """)
    assert "SERVE_PARITY_OK" in out
