"""Tile-autotuner, tile-padding and fused-epilogue tests.

Covers the three new kernel-layer seams:

  * ``kernels/tuning.py`` — the deterministic heuristic (never degenerates
    to tiny tiles), the JSON cache round-trip (second lookup measures
    NOTHING), and the autotune-off fallback;
  * the flash wrapper's pad-to-tile contract — non-divisor axis lengths are
    padded (masked keys / sliced query rows) instead of shrinking the tile,
    with exact parity and zero gradient leakage into the pad;
  * ``ops.gated_combine`` — the fused gate epilogue vs the jnp reference,
    forward and gradients, scalar- and token-mode gate shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.branches import gated_combine_ref, repeat_kv
from repro.kernels import ops, ref, tuning

KEY = jax.random.PRNGKey(99)
TOL = dict(atol=1e-5, rtol=1e-5)


@pytest.fixture(autouse=True)
def _tuning_sandbox(tmp_path, monkeypatch):
    """Point the tuning cache at a throwaway file and reset memory state."""
    monkeypatch.setenv(tuning.ENV_CACHE, str(tmp_path / "tuning.json"))
    monkeypatch.delenv(tuning.ENV_AUTOTUNE, raising=False)
    tuning.clear_memory_cache()
    yield
    tuning.clear_memory_cache()


# ---------------------------------------------------------------------------
# heuristic
# ---------------------------------------------------------------------------

def test_heuristic_tile_never_degenerates():
    # primes / ragged leftovers used to collapse the divisor rule to tile 1
    for n in (257, 263, 131, 97, 1000, 1536, 520):
        t = tuning.heuristic_tile(n, 256)
        assert t % 8 == 0
        assert t >= min(tuning.round_up(n, 8), 256) // 2
        assert t <= max(256, tuning.round_up(n, 8))


def test_heuristic_tile_small_axis_pads_to_sublane():
    assert tuning.heuristic_tile(4, 256) == 8      # pad up, don't shrink
    assert tuning.heuristic_tile(48, 256) == 48
    assert tuning.heuristic_tile(256, 256) == 256
    assert tuning.heuristic_tile(512, 256) == 256  # exact divisor kept


def test_shape_bucket():
    assert tuning.shape_bucket(1) == 1
    assert tuning.shape_bucket(256) == 256
    assert tuning.shape_bucket(257) == 512


# ---------------------------------------------------------------------------
# cache round-trip
# ---------------------------------------------------------------------------

def test_autotune_cache_round_trip(monkeypatch):
    monkeypatch.setenv(tuning.ENV_AUTOTUNE, "1")
    calls = []

    def measure(tq, tk):
        calls.append((tq, tk))
        return 1.0 if (tq, tk) != (128, 256) else 0.5   # winner: (128, 256)

    kw = dict(n_q=300, n_k=300, d=32, dtype=jnp.float32, interpret=True)
    tiles = tuning.get_tiles("flash", measure=measure, **kw)
    assert tiles == (128, 256)
    assert calls, "first resolution must measure"
    n_first = len(calls)

    def boom(tq, tk):
        raise AssertionError("cache hit must not re-measure")

    # same bucket (any n in (256, 512]) → pure lookup, measure never invoked
    assert tuning.get_tiles("flash", measure=boom, **kw) == (128, 256)
    assert tuning.get_tiles("flash", measure=boom,
                            n_q=400, n_k=511, d=32, dtype=jnp.float32,
                            interpret=True) == (128, 256)
    assert len(calls) == n_first

    # the persisted JSON survives a cold in-memory state (fresh process)
    tuning.clear_memory_cache()
    assert tuning.get_tiles("flash", measure=boom, **kw) == (128, 256)
    assert tuning.cache_path().exists()


def test_variant_isolates_cache_entries(monkeypatch):
    """Flash mask modes do different in-kernel work — causal / block-causal /
    plain must never share a cache entry."""
    monkeypatch.setenv(tuning.ENV_AUTOTUNE, "1")
    kw = dict(n_q=300, n_k=300, d=32, dtype=jnp.float32, interpret=True)
    tuning.get_tiles("flash", variant="plain",
                     measure=lambda tq, tk: 1.0 if (tq, tk) != (64, 128) else 0.1,
                     **kw)
    got = tuning.get_tiles("flash", variant="causal",
                           measure=lambda tq, tk: 1.0 if (tq, tk) != (256, 256) else 0.1,
                           **kw)
    assert got == (256, 256)                        # measured, not plain's hit
    assert tuning.get_tiles("flash", variant="plain", measure=None,
                            **kw) == (64, 128)
    assert tuning.flash_variant(True, False, 1) == "causal"
    assert tuning.flash_variant(False, True, 8) == "blockcausal8"
    assert tuning.flash_variant(False, False, 1) == "plain"


def test_compute_and_layout_isolate_cache_entries(monkeypatch):
    """The guard for the precision contract + batch layouts: a tile tuned
    under one compute dtype (bf16/fp8 operands) or one layout (packed
    varlen vs padded) must NEVER be replayed for another — the cost profile
    differs, so the cached winner is invalid there.  fp32/default compute
    deliberately shares the pre-contract key (old entries stay valid)."""
    monkeypatch.setenv(tuning.ENV_AUTOTUNE, "1")
    kw = dict(n_q=300, n_k=300, d=32, dtype=jnp.float32, interpret=True)
    tuning.get_tiles("flash",
                     measure=lambda tq, tk: 1.0 if (tq, tk) != (64, 128) else 0.1,
                     **kw)
    # different compute dtype: fresh measurement, not the fp32 hit
    got = tuning.get_tiles("flash", compute="bfloat16",
                           measure=lambda tq, tk: 1.0 if (tq, tk) != (256, 256) else 0.1,
                           **kw)
    assert got == (256, 256)
    # different layout: fresh measurement too
    got = tuning.get_tiles("flash", layout="varlen",
                           measure=lambda tq, tk: 1.0 if (tq, tk) != (128, 128) else 0.1,
                           **kw)
    assert got == (128, 128)
    # fp8 compute isolated from bf16 AND fp32
    got = tuning.get_tiles("flash", compute="float8_e4m3fn",
                           measure=lambda tq, tk: 1.0 if (tq, tk) != (64, 256) else 0.1,
                           **kw)
    assert got == (64, 256)
    # all four entries still resolve independently with no re-measurement
    def boom(tq, tk):
        raise AssertionError("cache hit must not re-measure")
    assert tuning.get_tiles("flash", measure=boom, **kw) == (64, 128)
    assert tuning.get_tiles("flash", compute="bfloat16", measure=boom,
                            **kw) == (256, 256)
    assert tuning.get_tiles("flash", layout="varlen", measure=boom,
                            **kw) == (128, 128)
    assert tuning.get_tiles("flash", compute="float8_e4m3fn", measure=boom,
                            **kw) == (64, 256)
    # compute="float32" IS the default key — pre-contract entries stay valid
    assert tuning.get_tiles("flash", compute="float32", measure=boom,
                            **kw) == (64, 128)
    # the storage dtype is part of the key independently of compute
    got = tuning.get_tiles("flash", n_q=300, n_k=300, d=32,
                           dtype=jnp.bfloat16, interpret=True,
                           measure=lambda tq, tk: 1.0 if (tq, tk) != (512, 512) else 0.1)
    assert got == (512, 512)


def test_kernel_call_rejects_non_dividing_tiles():
    from repro.kernels.flash import flash_attention_kernel_call
    q = jnp.zeros((1, 1, 300, 16))
    k = v = jnp.zeros((1, 300, 16))
    bias = jnp.zeros((1, 300), jnp.float32)
    with pytest.raises(ValueError, match="tiles must divide"):
        flash_attention_kernel_call(q, k, v, bias, n_heads=1, tq=256, tk=300,
                                    interpret=True)


def test_autotune_off_uses_heuristic_and_writes_nothing():
    def boom(tq, tk):
        raise AssertionError("autotune off must not measure")

    tiles = tuning.get_tiles("flash", n_q=257, n_k=64, d=32,
                             dtype=jnp.float32, interpret=True, measure=boom)
    assert tiles == (tuning.heuristic_tile(257, 256),
                     tuning.heuristic_tile(64, 256))
    assert not tuning.cache_path().exists()


def test_tune_flash_end_to_end(monkeypatch):
    """The real measurement path: tiny shape, interpret mode, twice."""
    monkeypatch.setenv(tuning.ENV_AUTOTUNE, "1")
    kw = dict(n_q=64, n_k=64, d=16, dtype=jnp.float32, interpret=True,
              bh=1, iters=1)
    tiles = tuning.tune_flash(**kw)
    assert tiles[0] % 8 == 0 and tiles[1] % 8 == 0
    import json
    data = json.loads(tuning.cache_path().read_text())
    assert len(data) == 1
    before = dict(data)
    assert tuning.tune_flash(**kw) == tiles          # hit: no re-measure
    assert json.loads(tuning.cache_path().read_text()) == before


# ---------------------------------------------------------------------------
# flash wrapper padding (tile need not divide the axis any more)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,L,tq,tk", [
    (120, 40, 64, 32),     # both axes padded
    (128, 48, 256, 32),    # q single tile, k padded
    (72, 24, 16, 16),      # small odd-ish axes
])
def test_flash_padding_parity(N, L, tq, tk):
    B, H, D = 1, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, N, H, D))
    k = jax.random.normal(ks[1], (B, L, H, D))
    v = jax.random.normal(ks[2], (B, L, H, D))
    kv = jnp.ones((B, L), bool).at[:, -L // 4:].set(False)
    out = ops.flash_attention(q, k, v, key_valid=kv, tq=tq, tk=tk)
    want = ref.flash_attention_ref(q, k, v, key_valid=kv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_flash_padding_grads_no_leak():
    """Gradients through the padded path match the unpadded reference —
    i.e. the pad rows/keys contribute exactly nothing."""
    B, N, L, H, D = 1, 72, 24, 1, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, N, H, D))
    k = jax.random.normal(ks[1], (B, L, H, D))
    v = jax.random.normal(ks[2], (B, L, H, D))
    w = jax.random.normal(ks[3], (B, N, H, D))

    def loss(fn, **kw):
        return lambda q, k, v: jnp.sum(fn(q, k, v, **kw) * w)

    got = jax.grad(loss(ops.flash_attention, tq=16, tk=16),
                   argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss(ref.flash_attention_ref), argnums=(0, 1, 2))(q, k, v)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ops-level GQA: un-repeated K/V through the kernel wrappers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", [1, 2, 4])
@pytest.mark.parametrize("op", ["ball", "flash", "local"])
def test_gqa_wrappers_match_repeated_reference(op, rep):
    B, N, Hkv, D = 1, 128, 1, 32
    ks = jax.random.split(jax.random.fold_in(KEY, rep), 4)
    q = jax.random.normal(ks[0], (B, N, Hkv * rep, D))
    k = jax.random.normal(ks[1], (B, N, Hkv, D))
    v = jax.random.normal(ks[2], (B, N, Hkv, D))
    w = jax.random.normal(ks[3], (B, N, Hkv * rep, D))
    mask = jnp.ones((B, N), bool).at[:, -N // 8:].set(False)

    if op == "ball":
        kfn = lambda q, k, v: ops.ball_attention(q, k, v, mask, 32)
        rfn = lambda q, k, v: ref.ball_attention_ref(
            q, repeat_kv(k, rep), repeat_kv(v, rep), mask, 32)
    elif op == "flash":
        kfn = lambda q, k, v: ops.flash_attention(q, k, v, key_valid=mask)
        rfn = lambda q, k, v: ref.flash_attention_ref(
            q, repeat_kv(k, rep), repeat_kv(v, rep), key_valid=mask)
    else:
        kfn = lambda q, k, v: ops.local_window_attention(q, k, v, 32, mask)
        rfn = lambda q, k, v: ref.local_window_attention_ref(
            q, repeat_kv(k, rep), repeat_kv(v, rep), 32, mask=mask)

    np.testing.assert_allclose(np.asarray(kfn(q, k, v)),
                               np.asarray(rfn(q, k, v)), atol=1e-4, rtol=1e-4)
    got = jax.grad(lambda q, k, v: jnp.sum(kfn(q, k, v) * w),
                   argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(lambda q, k, v: jnp.sum(rfn(q, k, v) * w),
                    argnums=(0, 1, 2))(q, k, v)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused gated-combine epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gate_shape", ["scalar", "token"])
@pytest.mark.parametrize("masked", [False, True])
def test_gated_combine_parity(gate_shape, masked):
    B, N, H, D = 2, 48, 3, 16
    ks = jax.random.split(KEY, 7)
    outs = tuple(jax.random.normal(ks[i], (B, N, H, D)) for i in range(3))
    gshape = (1, 1, H, 1) if gate_shape == "scalar" else (B, N, H, 1)
    gates = tuple(jax.nn.sigmoid(jax.random.normal(ks[3 + i], gshape))
                  for i in range(3))
    mask = jnp.ones((B, N), bool).at[:, -N // 4:].set(False) if masked else None

    out = ops.gated_combine(outs, gates, mask)
    want = gated_combine_ref(outs, gates, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)

    def loss(fn):
        def f(outs, gates):
            return jnp.sum(fn(outs, gates, mask) ** 2)
        return f

    got = jax.grad(loss(ops.gated_combine), argnums=(0, 1))(outs, gates)
    ref_g = jax.grad(loss(gated_combine_ref), argnums=(0, 1))(outs, gates)
    for g, r in zip(jax.tree.leaves(got), jax.tree.leaves(ref_g)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=1e-4, rtol=1e-4)
