"""Property-based invariants of the paged-KV host controller (hypothesis).

Random operation sequences against :mod:`repro.serving.paged_cache`, checked
against an independent model after EVERY op:

* allocator: a block is free XOR refcounted, counts mirror a dict model,
  double-free / free-incref always raise — no leaks under any interleaving;
* controller: admit / decode-step / retire / fork interleavings keep
  refcounts equal to live references (slot table entries + prefix nodes);
* prefix tree: hash-chained lookups return exactly the pages before the
  first token difference — no aliasing between prompts, ever.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[test]); skipping module")
from hypothesis import given, settings, strategies as st

from repro.serving.paged_cache import BlockAllocator, PagedKVCache, PrefixCache


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "incref", "decref"]),
                              st.integers(0, 15)), max_size=60),
       n=st.integers(1, 8))
def test_allocator_matches_refcount_model(ops, n):
    a = BlockAllocator(n)
    model: dict[int, int] = {}               # live block -> refcount
    for op, arg in ops:
        if op == "alloc":
            b = a.alloc()
            if b is None:
                assert len(model) == n       # exhausted ⇔ all blocks live
            else:
                assert b not in model
                model[b] = 1
        elif op == "incref":
            b = arg % n
            if b in model:
                model[b] += 1
                assert a.incref(b) == model[b]
            else:
                with pytest.raises(RuntimeError):
                    a.incref(b)
        else:
            b = arg % n
            if b in model:
                model[b] -= 1
                assert a.decref(b) == model[b]
                if model[b] == 0:
                    del model[b]
            else:
                with pytest.raises(RuntimeError):
                    a.decref(b)              # double free always raises
        a.check()
        assert a.live_count == len(model)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_controller_random_lifecycle_keeps_refcounts_exact(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    S = 3
    kv = PagedKVCache(n_slots=S, num_blocks=10, page=4, n_pages=4,
                      prefix_cache=data.draw(st.booleans()))
    prompts: dict[int, np.ndarray] = {}
    for _ in range(data.draw(st.integers(5, 40))):
        op = data.draw(st.sampled_from(["admit", "step", "step", "retire",
                                        "fork"]))
        free = [s for s in range(S) if not kv.active[s]]
        act = [s for s in range(S) if kv.active[s]]
        if op == "admit" and free:
            s = free[0]
            length = int(rng.integers(1, kv.capacity + 1))
            prompts[s] = rng.integers(0, 30, length).astype(np.int32)
            kv.admit(s, prompts[s])
        elif op == "step" and act:
            s = act[int(rng.integers(len(act)))]
            if int(kv.lengths[s]) < kv.capacity:
                old = int(kv.lengths[s])
                try:
                    kv.prepare_append(s)
                except RuntimeError:         # pool exhausted: legal outcome
                    kv.check()
                    continue
                kv.committed(s)
                kv.seal_prompt_pages(s, prompts[s], old)
        elif op == "retire" and act:
            kv.retire(act[int(rng.integers(len(act)))])
        elif op == "fork" and act and free:
            src = act[int(rng.integers(len(act)))]
            kv.fork(free[0], src)
            prompts[free[0]] = prompts[src]
        kv.check()                           # refcounts == live references
    for s in range(S):
        if kv.active[s]:
            kv.retire(s)
    kv.check()
    live = len(kv.prefix) if kv.prefix is not None else 0
    assert kv.allocator.live_count == live   # slots gone ⇒ only tree refs


@settings(max_examples=40, deadline=None)
@given(tokens=st.lists(st.integers(0, 9), min_size=8, max_size=16),
       mut_at=st.integers(0, 7), mut_to=st.integers(0, 9))
def test_prefix_lookup_never_aliases(tokens, mut_at, mut_to):
    page = 4
    a = BlockAllocator(16)
    pc = PrefixCache(a, page)
    t1 = np.asarray(tokens, np.int32)
    t2 = t1.copy()
    t2[mut_at] = mut_to
    for pg in range(len(t1) // page):
        b = a.alloc()
        pc.insert(t1, pg, b)
        a.decref(b)
    cached = pc.lookup(t1)
    assert len(cached) == len(t1) // page    # full chain round-trips
    if (t1 == t2).all():
        assert pc.lookup(t2) == cached
    else:
        diff_pg = int(np.flatnonzero(t1 != t2)[0]) // page
        assert pc.lookup(t2) == cached[:diff_pg]
    pc.clear()
    a.check()
    assert a.free_count == 16                # tree refs fully released


@settings(max_examples=20, deadline=None)
@given(n_prompts=st.integers(1, 4), seed=st.integers(0, 2**32 - 1),
       n_evict=st.integers(0, 8))
def test_prefix_eviction_only_drops_leaves(n_prompts, seed, n_evict):
    page = 4
    a = BlockAllocator(32)
    pc = PrefixCache(a, page)
    rng = np.random.default_rng(seed)
    for _ in range(n_prompts):
        toks = rng.integers(0, 3, 12).astype(np.int32)
        for pg in range(len(toks) // page):
            b = a.alloc()
            pc.insert(toks, pg, b)           # may dedup: first writer wins
            a.decref(b)                      # caller ref gone either way
    before = len(pc)
    dropped = pc.evict_lru(n_evict)
    assert dropped == min(n_evict, before)
    a.check()
    # interior nodes survive while any child holds them: every remaining
    # node's parent chain is intact (lookup of its own prefix still works)
    assert a.live_count == len(pc)
