"""Unit tests for the HLO analyzer (roofline inputs)."""

import textwrap

from repro.launch.hlo_analysis import HloModule, _INSTR_RE, _type_bytes

SAMPLE = textwrap.dedent("""\
    HloModule jit_step

    %body (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
      %p = (s32[], f32[16,128]{1,0}) parameter(0)
      %w = f32[128,128]{1,0} get-tuple-element(%p), index=1
      %x = f32[16,128]{1,0} get-tuple-element(%p), index=1
      %ag = f32[16,512]{1,0} all-gather(%x), replica_groups=[4]<=[4], dimensions={1}
      %dot.1 = f32[16,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %t = (s32[], f32[16,128]{1,0}) tuple(%p, %dot.1)
    }

    %cond (p2: (s32[], f32[16,128])) -> pred[] {
      %p2 = (s32[], f32[16,128]{1,0}) parameter(0)
      %iv = s32[] get-tuple-element(%p2), index=0
      %lim = s32[] constant(8)
      ROOT %lt = pred[] compare(%iv, %lim), direction=LT
    }

    ENTRY %main (a: f32[16,128]) -> f32[16,128] {
      %a = f32[16,128]{1,0} parameter(0)
      %init = (s32[], f32[16,128]{1,0}) tuple(%a, %a)
      %wl = (s32[], f32[16,128]{1,0}, /*index=2*/f32[8,8]{1,0:T(8,128)(2,1)}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
      %ar = f32[16,128]{1,0} all-reduce(%a), replica_groups=[4]<=[4], to_apply=%body
      ROOT %o = f32[16,128]{1,0} get-tuple-element(%wl), index=1
    }
""")


def test_instr_regex_survives_tuple_types_and_layouts():
    m = _INSTR_RE.match('  %wl = (s32[], f32[16,128]{1,0}, /*index=2*/f32[8,8]'
                        '{1,0:T(8,128)(2,1)}) while(%init), condition=%c, body=%b')
    assert m and m.group(3) == "while"
    m = _INSTR_RE.match('  %d = f32[16,128]{1,0:T(8,128)} dot(%x, %w), '
                        'lhs_contracting_dims={1}')
    assert m and m.group(3) == "dot"


def test_type_bytes():
    assert _type_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _type_bytes("(s32[], bf16[4,8]{1,0})") == 4 + 4 * 8 * 2
    assert _type_bytes("pred[]") == 1


def test_loop_weighted_flops_and_collectives():
    mod = HloModule(SAMPLE)
    # while body runs 8× (known_trip_count)
    assert mod.mult["body"] == 8
    # dot: 2*16*128*128 per trip × 8 trips
    assert mod.dot_flops() == 2 * 16 * 128 * 128 * 8
    coll = mod.collectives()
    assert coll["all-gather"]["bytes"] == 16 * 512 * 4 * 8          # in loop
    assert coll["all-reduce"]["bytes"] == 16 * 128 * 4              # outside
    assert coll["all-gather"]["count"] == 8


def test_trip_count_fallback_from_condition_constant():
    # strip the backend_config → falls back to the condition's s32 constant
    stripped = SAMPLE.replace(', backend_config={"known_trip_count":{"n":"8"}}', "")
    mod = HloModule(stripped)
    assert mod.mult["body"] == 8
